"""Speculative decode: exact-match preservation, CacheTable invariants,
accounting, KV rollback, pricing laws, and the autotuned triple space.

The load-bearing invariant everywhere: speculation changes WHICH positions
a round pays for, never the tokens — every speculative path must be
bit-identical, token by token, to the PR 5 sequential decode it rides on.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.autotune import (SpaceSpec, enumerate_speculative_space,
                            lm_decode_schedules, select_speculative,
                            speculative_draft_legal)
from repro.autotune.space import decode_legal
from repro.autotune.target import DesignTarget
from repro.config import FixedPointConfig
from repro.core.hls import (estimate_lm_decode, estimate_speculative,
                            expected_round_tokens)
from repro.core.quant.fixed_point import is_native_int, quantize_np
from repro.kernels.decode_step import rnn_decode_step
from repro.kernels.schedule import KernelSchedule
from repro.models import build_model
from repro.models.decode import (cache_specs, decode_step, decode_steps,
                                 kv_trim)
from repro.registry import get_config
from repro.serving import LMServingEngine
from repro.serving.speculative import (CacheTable, SpecConfig, accept_chunk,
                                       speculative_generate)
from repro.testing import tiny_config


# ---------------------------------------------------------------------------
# shared model fixtures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm():
    cfg = tiny_config(get_config("stablelm-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _zero_cache(cfg, batch, seq):
    specs = cache_specs(cfg, batch, seq, "float32")
    return {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
            for k, s in specs.items()}


# ---------------------------------------------------------------------------
# CacheTable (SNIPPETS.md §3 pie pattern): unit + property invariants
# ---------------------------------------------------------------------------


def test_cache_table_hit_after_insert_and_promotion():
    t = CacheTable(n=3, capacity=8, lru_size=2)
    t.insert([1, 2, 3], 7)
    assert t.lookup([1, 2, 3]) == 7
    t.insert([1, 2, 3], 9)             # newer candidate promoted to front
    assert t.lookup([1, 2, 3]) == 9
    t.insert([1, 2, 3], 7)             # promote back, no duplicate
    assert t.candidates([1, 2, 3]) == [7, 9]
    t.insert([1, 2, 3], 5)             # row bounded by lru_size=2
    assert t.candidates([1, 2, 3]) == [5, 7]
    assert len(t.candidates([1, 2, 3])) <= 2


def test_cache_table_lru_eviction_order_is_deterministic():
    t = CacheTable(n=2, capacity=3, lru_size=2)
    t.insert([1, 1], 1)
    t.insert([2, 2], 2)
    t.insert([3, 3], 3)
    assert t.lookup([1, 1]) == 1       # touch (1,1): now (2,2) is LRU
    t.insert([4, 4], 4)                # over capacity -> evict (2,2)
    assert t.lookup([2, 2]) is None
    assert t.lookup([1, 1]) == 1
    assert t.evictions == 1
    assert len(t) == 3


def test_cache_table_observe_and_draft_follow_a_cycle():
    t = CacheTable(n=3, capacity=64, lru_size=4)
    stream = [1, 2, 3, 4, 5] * 4
    t.observe(stream)
    # drafts from the cycle's suffix reproduce the cycle
    assert t.draft(stream, 5) == [1, 2, 3, 4, 5]
    # incremental observe via watermark sees only new targets
    hits0 = t.hits
    t.observe(stream + [1, 2], start=len(stream))
    assert t.draft(stream + [1, 2], 3) == [3, 4, 5]
    assert t.hits > hits0


def test_cache_table_rejects_bad_params_and_short_contexts():
    with pytest.raises(ValueError):
        CacheTable(n=0)
    with pytest.raises(ValueError):
        CacheTable(capacity=0)
    t = CacheTable(n=3)
    t.insert([1, 2], 9)                # wrong-length context: ignored
    assert len(t) == 0
    # drafting from a too-short stream falls back to repeat-last
    assert t.draft([5], 3) == [5, 5, 5]


@settings(max_examples=25)
@given(capacity=st.integers(1, 6), lru=st.integers(1, 3),
       seed=st.integers(0, 10_000), nops=st.integers(1, 40))
def test_cache_table_properties(capacity, lru, seed, nops):
    """size <= capacity always; no duplicate candidates; rows <= lru_size;
    a just-inserted pair is an immediate hit."""
    rnd = np.random.RandomState(seed)
    t = CacheTable(n=2, capacity=capacity, lru_size=lru)
    for _ in range(nops):
        ctx = [int(x) for x in rnd.randint(0, 4, size=2)]
        nxt = int(rnd.randint(0, 6))
        t.insert(ctx, nxt)
        assert len(t) <= capacity
        assert t.lookup(ctx) == nxt    # hit after insert, MRU first
        row = t.candidates(ctx)
        assert len(row) == len(set(row)) <= lru


# ---------------------------------------------------------------------------
# accept_chunk: the sequential tick's advance logic, replayed over a chunk
# ---------------------------------------------------------------------------


def test_accept_chunk_accept_all_emits_bonus_token():
    toks = [3, 1]                      # plen 2, generation phase
    adv = accept_chunk([1, 5, 6], [5, 6, 7], tokens=toks, plen=2, pos=1,
                       max_new=16)
    assert adv.emitted == [5, 6, 7]    # K accepted drafts + the bonus
    assert (adv.drafted, adv.accepted, adv.rejected) == (2, 2, 0)
    assert adv.advanced == 3 and not adv.done


def test_accept_chunk_reject_first_draft():
    adv = accept_chunk([1, 9, 9], [5, 6, 7], tokens=[3, 1], plen=2, pos=1,
                       max_new=16)
    assert adv.emitted == [5]          # the verify pass's own token only
    assert (adv.drafted, adv.accepted, adv.rejected) == (2, 0, 2)
    assert adv.advanced == 1


def test_accept_chunk_teacher_forces_prompt_then_emits():
    # chunk covers prompt positions: walk teacher-forces through them and
    # emits only after leaving the prompt — multi-token prompt consumption
    toks = [4, 7, 2, 9]                # plen 4, pos 0
    adv = accept_chunk([4, 7, 2, 9], [1, 1, 1, 8], tokens=toks, plen=4,
                       pos=0, max_new=16)
    assert adv.emitted == [8]          # only the post-prompt position
    assert adv.advanced == 4
    assert adv.drafted == 0 == adv.accepted == adv.rejected


def test_accept_chunk_stops_at_max_new_and_max_seq():
    adv = accept_chunk([1, 5, 6], [5, 6, 7], tokens=[3, 1], plen=2, pos=1,
                       max_new=1)
    assert adv.emitted == [5] and adv.done
    adv = accept_chunk([1, 5, 6], [5, 6, 7], tokens=[3, 1], plen=2, pos=1,
                       max_new=16, max_seq=3)
    assert adv.done and adv.advanced == 1


@settings(max_examples=30)
@given(seed=st.integers(0, 10_000), k=st.integers(0, 5),
       plen=st.integers(1, 4), max_new=st.integers(1, 6))
def test_accept_chunk_exact_sum_property(seed, k, plen, max_new):
    """drafted == accepted + rejected for arbitrary chunks, and emitted
    tokens never exceed the chunk length."""
    rnd = np.random.RandomState(seed)
    toks = [int(x) for x in rnd.randint(0, 8, size=plen)]
    pos = int(rnd.randint(0, plen))
    n_known = len(toks) - pos
    S = k + 1
    inputs = [toks[pos + i] if i < n_known else int(rnd.randint(0, 8))
              for i in range(S)]
    greedy = [int(x) for x in rnd.randint(0, 8, size=S)]
    adv = accept_chunk(inputs, greedy, tokens=toks, plen=plen, pos=pos,
                       max_new=max_new)
    assert adv.drafted == adv.accepted + adv.rejected
    assert adv.drafted >= 0 and adv.accepted >= 0 and adv.rejected >= 0
    assert len(adv.emitted) <= S
    assert adv.advanced >= 1           # position 0 always advances


# ---------------------------------------------------------------------------
# decode_steps / kv_trim: the multi-token verify primitives bit-match the
# sequential step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched", [
    None,
    KernelSchedule(reuse_factor=2, block_batch=8, backend="pallas_interpret"),
    KernelSchedule(reuse_factor=4, block_batch=8, backend="xla"),
], ids=["default", "R2-pallas", "R4-xla"])
def test_decode_steps_bit_matches_sequential_chain(lm, sched):
    cfg, params = lm
    B, S = 2, 5
    zero = _zero_cache(cfg, B, 16)
    rng = np.random.RandomState(0)
    toks = rng.randint(0, cfg.vocab_size, size=(B, S)).astype(np.int32)
    pos0 = jnp.asarray([0, 3], jnp.int32)

    cache = dict(zero)
    outs = []
    for i in range(S):
        li, cache = decode_step(cfg, params, cache,
                                jnp.asarray(toks[:, i:i + 1]),
                                pos0 + i, schedule=sched)
        outs.append(np.asarray(li))
    seq = np.concatenate(outs, 1)
    bl, bc = decode_steps(cfg, params, dict(zero), jnp.asarray(toks), pos0,
                          schedule=sched)
    assert (np.asarray(bl) == seq).all()
    for k in cache:
        assert (np.asarray(bc[k]) == np.asarray(cache[k])).all(), k


def test_kv_trim_rolls_back_to_sequential_prefix(lm):
    cfg, params = lm
    B = 2
    zero = _zero_cache(cfg, B, 16)
    rng = np.random.RandomState(1)
    toks = rng.randint(0, cfg.vocab_size, size=(B, 6)).astype(np.int32)
    pos0 = jnp.asarray([0, 2], jnp.int32)

    cache = dict(zero)
    for i in range(3):
        _, cache = decode_step(cfg, params, cache,
                               jnp.asarray(toks[:, i:i + 1]), pos0 + i)
    ref = {k: np.asarray(v) for k, v in cache.items()}
    dirty = dict(cache)
    for i in range(3, 6):              # wrong-branch speculative writes
        _, dirty = decode_step(cfg, params, dirty,
                               jnp.asarray(toks[:, i:i + 1]), pos0 + i)
    trimmed = kv_trim(dirty, pos0 + 3)
    for k in ref:
        assert (np.asarray(trimmed[k]) == ref[k]).all(), k
    # decoding onward from the trimmed cache == from the clean prefix
    l1, _ = decode_step(cfg, params, dict(trimmed),
                        jnp.asarray(toks[:, 3:4]), pos0 + 3)
    l2, _ = decode_step(cfg, params, {k: jnp.asarray(v)
                                      for k, v in ref.items()},
                        jnp.asarray(toks[:, 3:4]), pos0 + 3)
    assert (np.asarray(l1) == np.asarray(l2)).all()


# ---------------------------------------------------------------------------
# engine-level exact match: speculative == PR 5 sequential, token by token
# ---------------------------------------------------------------------------


def _serve(cfg, params, prompts, max_new, schedule=None, spec=None,
           max_seq=64):
    eng = LMServingEngine(cfg, params, max_batch=len(prompts) + 1,
                          max_seq=max_seq, schedule=schedule, spec=spec)
    ids = [eng.add_request(list(p), max_new=max_new) for p in prompts]
    out = eng.run_to_completion()
    return [out[i] for i in ids], eng


R1P = KernelSchedule(reuse_factor=1, block_batch=8, backend="pallas_interpret")
R4P = KernelSchedule(reuse_factor=4, block_batch=8, backend="pallas_interpret")
R8X = KernelSchedule(reuse_factor=8, block_batch=8, backend="xla")
R1X = KernelSchedule(reuse_factor=1, block_batch=8, backend="xla")


@pytest.mark.parametrize("sched,spec", [
    (None, SpecConfig(k=3)),
    (R1P, SpecConfig(k=2)),
    (R4P, SpecConfig(k=4, trim=True)),
    (R1X, SpecConfig(k=2, draft=R8X)),
    (None, SpecConfig(k=3, draft=R8X)),
], ids=["ngram-default-k3", "ngram-R1p-k2", "ngram-R4p-k4-trim",
        "draftR8-R1x-k2", "draftR8-default-k3"])
def test_engine_speculative_bit_identical_to_sequential(lm, sched, spec):
    cfg, params = lm
    rng = np.random.RandomState(2)
    prompts = [list(rng.randint(0, cfg.vocab_size, size=4)) for _ in range(3)]
    ref, _ = _serve(cfg, params, prompts, 10, schedule=sched)
    got, eng = _serve(cfg, params, prompts, 10, schedule=sched, spec=spec)
    assert got == ref                  # token-by-token bit identity
    acc = eng.verify_spec_accounting()
    (key,) = acc
    assert acc[key]["drafted"] == acc[key]["accepted"] + acc[key]["rejected"]
    dec = eng._decoders[key]
    assert dec.spec_dec.verify_traces == 1      # ONE verify trace per key
    assert dec.spec_dec.draft_traces <= 1       # ONE draft trace (if any)


def test_engine_k0_disables_speculation_cleanly(lm):
    cfg, params = lm
    eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                          spec=SpecConfig(k=0))
    assert eng.keys() == ["default"]   # same key as a plain engine
    rid = eng.add_request([3, 1, 4], max_new=4)
    out = eng.run_to_completion()
    plain, _ = _serve(cfg, params, [[3, 1, 4]], 4)
    assert list(out[rid]) == plain[0]
    assert eng.verify_spec_accounting() == {}   # no speculative keys
    rep = eng.serve_report()["default"]
    assert rep["accept_rate"] is None and rep["spec"] is None
    # a per-request k=0 override on a spec-default engine opts OUT
    eng2 = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                           spec=SpecConfig(k=2))
    eng2.add_request([3, 1, 4], max_new=4, spec=SpecConfig(k=0))
    assert "default" in eng2.keys()


def test_engine_spec_key_isolated_from_plain_traffic(lm):
    cfg, params = lm
    eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
    r1 = eng.add_request([5, 2], max_new=3)
    r2 = eng.add_request([5, 2], max_new=3, spec=SpecConfig(k=2))
    out = eng.run_to_completion()
    assert list(out[r1]) == list(out[r2])       # exact match across keys
    keys = eng.keys()
    assert "default" in keys and "default-spec[k2_ngram3]" in keys
    # schedule part of the suffixed key still round-trips through from_key
    spec_key = [k for k in keys if "spec" in k][0]
    assert spec_key.startswith("default")


def test_engine_spec_slot_reuse_and_queue_full(lm):
    cfg, params = lm
    eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                          spec=SpecConfig(k=2))
    a = eng.add_request([1, 2], max_new=2)
    b = eng.add_request([3, 4], max_new=2)
    assert eng.add_request([5, 6], max_new=2) is None   # pool full
    out = eng.run_to_completion()
    assert set(out) == {a, b}
    c = eng.add_request([5, 6], max_new=2)              # slot freed
    assert c is not None
    out2 = eng.run_to_completion()
    ref, _ = _serve(cfg, params, [[5, 6]], 2)
    assert list(out2[c]) == ref[0]


def test_engine_spec_serve_report_and_accounting_columns(lm):
    cfg, params = lm
    rng = np.random.RandomState(3)
    prompts = [list(rng.randint(0, cfg.vocab_size, size=3))
               for _ in range(2)]
    _, eng = _serve(cfg, params, prompts, 6, spec=SpecConfig(k=3))
    (key,) = eng.keys()
    rep = eng.serve_report()[key]
    sd = rep["spec"]
    assert rep["draft_traces"] == 0             # n-gram drafts never trace
    assert sd["k"] == 3 and sd["draft"] is None and sd["ngram_n"] == 3
    assert sd["drafted"] == sd["accepted"] + sd["rejected"]
    assert sd["rounds"] > 0 and sd["verify_traces"] == 1
    assert rep["accept_rate"] == sd["accept_rate"]
    # tokens/s counts ACCEPTED tokens only: the measured token count is
    # what the requests actually received, not what was drafted
    emitted = 2 * 6                             # 2 requests x max_new
    assert rep["measured"]["tokens"] <= emitted
    acc = eng.verify_spec_accounting()[key]
    assert acc["drafted"] == sd["drafted"]
    # tamper -> the exact-sum check must raise, naming the key
    eng._decoders[key].spec_dec.rejected += 1
    with pytest.raises(AssertionError, match="accounting broken"):
        eng.verify_spec_accounting()


# ---------------------------------------------------------------------------
# generic driver: exactness over stateless oracles, fp incl. native int8
# ---------------------------------------------------------------------------


def _rnn_oracle(fp, schedule, vocab=12, hidden=8, seed=0):
    """A toy stateless LM over ``rnn_decode_step``: one-hot embed, run the
    (optionally native-int) scheduled recurrent step over the context,
    project h onto the vocab.  The fp/native path is exactly the kernels'
    — what the engine cannot reach for dense LMs, the driver covers."""
    rng = np.random.RandomState(seed)
    W = quantize_np(rng.randn(vocab, 4 * hidden).astype(np.float32) * .4, fp) \
        if fp else rng.randn(vocab, 4 * hidden).astype(np.float32) * .4
    U = quantize_np(rng.randn(hidden, 4 * hidden).astype(np.float32) * .4, fp) \
        if fp else rng.randn(hidden, 4 * hidden).astype(np.float32) * .4
    b = np.zeros((4 * hidden,), np.float32)
    E = rng.randn(hidden, vocab).astype(np.float32)
    Wj, Uj, bj, Ej = map(jnp.asarray, (W, U, b, E))

    def step_fn(ctx):
        h = jnp.zeros((1, hidden), jnp.float32)
        c = jnp.zeros((1, hidden), jnp.float32)
        for t in ctx:
            x = jnp.zeros((1, vocab), jnp.float32).at[0, int(t)].set(1.0)
            h, (h, c) = rnn_decode_step("lstm", x, (h, c), Wj, Uj, bj,
                                        schedule=schedule, fp=fp)
        return np.asarray(h @ Ej)[0]

    return step_fn


def _sequential_greedy(step_fn, prompt, max_new):
    toks = list(prompt)
    for _ in range(max_new):
        toks.append(int(np.argmax(np.asarray(step_fn(toks)))))
    return toks[len(prompt):]


@pytest.mark.parametrize("fp,sched", [
    (None, None),
    (FixedPointConfig(16, 6), None),
    (FixedPointConfig(8, 3),
     KernelSchedule(reuse_factor=2, block_batch=8,
                    backend="pallas_interpret")),
], ids=["float", "emulated-fp16.6", "native-int8"])
@pytest.mark.parametrize("k", [2, 4])
def test_speculative_generate_exact_across_fp(fp, sched, k):
    if fp is not None and sched is not None:
        assert is_native_int(fp)       # the native kernel body runs
    step_fn = _rnn_oracle(fp, sched)
    prompt = [3, 1, 3, 1]
    ref = _sequential_greedy(step_fn, prompt, 8)
    got, stats = speculative_generate(step_fn, prompt, 8, k=k)
    assert got == ref                  # bit-identical under every fp
    assert stats["drafted"] == stats["accepted"] + stats["rejected"]
    assert stats["rounds"] >= 1


def test_speculative_generate_accept_all_and_reject_all():
    step_fn = _rnn_oracle(None, None)
    prompt = [2, 5, 2]
    ref = _sequential_greedy(step_fn, prompt, 6)

    def oracle_draft(toks, k):         # accept-all: draft the true greedy
        out, cur = [], list(toks)
        for _ in range(k):
            nxt = int(np.argmax(np.asarray(step_fn(cur))))
            out.append(nxt)
            cur.append(nxt)
        return out

    # max_new = 8 is two FULL K=3 rounds (4 emits each): no draft lands
    # past the max_new cap, so perfect drafts mean zero rejections
    ref8 = _sequential_greedy(step_fn, prompt, 8)
    got, stats = speculative_generate(step_fn, prompt, 8, k=3,
                                      draft_fn=oracle_draft)
    assert got == ref8
    assert stats["rejected"] == 0 and stats["accepted"] == 6

    def wrong_draft(toks, k):          # reject-all: never the greedy token
        out, cur = [], list(toks)
        for _ in range(k):
            nxt = (int(np.argmax(np.asarray(step_fn(cur)))) + 1) % 12
            out.append(nxt)
            cur.append(nxt)
        return out

    got, stats = speculative_generate(step_fn, prompt, 6, k=3,
                                      draft_fn=wrong_draft)
    assert got == ref                  # exactness survives total rejection
    assert stats["accepted"] == 0 and stats["rejected"] == stats["drafted"]
    # K=0 degenerates to plain sequential greedy, no drafts at all
    got, stats = speculative_generate(step_fn, prompt, 6, k=0)
    assert got == ref and stats["drafted"] == 0


# ---------------------------------------------------------------------------
# pricing laws + the (draft, verify, K) space
# ---------------------------------------------------------------------------


def test_expected_round_tokens_limits():
    assert expected_round_tokens(4, 0.0) == 1.0
    assert expected_round_tokens(4, 1.0) == 5.0
    assert expected_round_tokens(0, 0.5) == 1.0
    a = [expected_round_tokens(3, r) for r in (0.1, 0.5, 0.9)]
    assert a == sorted(a)              # monotone in accept_rate
    with pytest.raises(ValueError):
        expected_round_tokens(-1, 0.5)
    with pytest.raises(ValueError):
        expected_round_tokens(2, 1.5)


def test_estimate_speculative_laws(lm):
    cfg, _ = lm
    verify = estimate_lm_decode(R1P, cfg)
    draft = estimate_lm_decode(
        KernelSchedule(reuse_factor=4, block_batch=8,
                       backend="pallas_interpret"), cfg)
    # K=0 is exactly sequential decode on the verify schedule
    e0 = estimate_speculative(None, verify, 0, 0.75)
    assert e0.speedup_vs_sequential() == pytest.approx(1.0)
    assert e0.tokens_per_cycle == pytest.approx(1 / verify.latency_cycles)
    # free n-gram drafts dominate model drafts at equal accept rate
    en = estimate_speculative(None, verify, 4, 0.75)
    em = estimate_speculative(draft, verify, 4, 0.75)
    assert en.tokens_per_cycle > em.tokens_per_cycle
    assert en.dsp < em.dsp             # and cost no silicon
    # speedup monotone in accept rate at fixed K
    sp = [estimate_speculative(None, verify, 4, r).speedup_vs_sequential()
          for r in (0.0, 0.4, 0.8)]
    assert sp == sorted(sp)
    row = em.report_row()
    assert row["draft_key"] == draft.schedule.key()
    assert row["dsp"] == verify.dsp + draft.dsp


def test_speculative_space_legality(lm):
    cfg, _ = lm
    sp = SpaceSpec(backends=("pallas_interpret",))
    pool = lm_decode_schedules(cfg, sp)
    assert pool and all(decode_legal(s) for s in pool)
    triples = enumerate_speculative_space(cfg, sp, ks=(1, 2))
    assert triples
    for draft, verify, k in triples:
        assert k >= 1
        assert decode_legal(verify)
        assert speculative_draft_legal(draft, verify)
        if draft is not None:
            assert draft.reuse_factor > verify.reuse_factor
    # determinism
    assert triples == enumerate_speculative_space(cfg, sp, ks=(1, 2))
    # draft legality rules directly
    assert speculative_draft_legal(None, R1P)
    assert not speculative_draft_legal(R1P, R1P)       # not strictly cheaper
    assert not speculative_draft_legal(R1P, R4P)       # denser than verify


def test_select_speculative_target_and_rerank(lm):
    cfg, _ = lm
    sp = SpaceSpec(backends=("pallas_interpret",))
    best = select_speculative(cfg, None, sp, ks=(2, 4))
    assert best.k == 4 and best.draft is None  # analytic: free drafts, max K
    # resource cap prices BOTH datapaths: cap below draft+verify forbids
    # model drafts but keeps the n-gram triple
    verify_dsp = estimate_lm_decode(R1P, cfg).dsp
    t = DesignTarget(max_dsp=verify_dsp, clock_mhz=200.0)
    pick = select_speculative(cfg, t, sp, ks=(2,))
    assert pick.draft is None
    with pytest.raises(ValueError, match="pruned every point"):
        select_speculative(cfg, DesignTarget(max_dsp=1), sp, ks=(2,))
    # measured re-rank: the HIGHEST measured tokens/s wins
    measured = {2: 100.0, 4: 300.0}
    pick = select_speculative(cfg, None, sp, ks=(2, 4),
                              measure_fn=lambda p: measured.get(p.k, 0.0),
                              measure_top_k=3)
    assert pick.k == 4


def test_spec_config_validation_and_key_tokens():
    with pytest.raises(ValueError):
        SpecConfig(k=-1)
    with pytest.raises(ValueError):
        SpecConfig(ngram_n=0)
    assert SpecConfig(k=0).key_token() == ""
    assert SpecConfig(k=4).key_token() == "spec[k4_ngram3]"
    tok = SpecConfig(k=2, draft=R8X, trim=True).key_token()
    assert "-" not in tok              # dash-free: from_key still parses
    # the full serving key round-trips its schedule part
    full = R1P.key() + "-" + tok
    assert KernelSchedule.from_key(full).key() == R1P.key()
