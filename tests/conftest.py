import os
import sys

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device (multi-device tests spawn subprocesses).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Install the deterministic hypothesis fallback before collection so
# property-test modules import even when hypothesis isn't in the container.
import _hypothesis_stub  # noqa: E402

_hypothesis_stub.install()

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture()
def rng():
    # function-scoped: every test draws from a fresh, fixed seed, so results
    # cannot depend on which other tests ran first (a session-scoped shared
    # stream made borderline-tolerance tests order-dependent)
    return np.random.RandomState(0)
