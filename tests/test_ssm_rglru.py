"""SSD (Mamba-2) and RG-LRU numerics: chunked vs naive recurrence, chunk-size
invariance, prefill->decode state handoff continuity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.rglru import _scan_linear_recurrence
from repro.models.ssm import _ssd_chunked


def _naive_ssd(xdt, log_a, B, C):
    b, s, h, p = xdt.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    y = np.zeros((b, s, h, p), np.float32)
    state = np.zeros((b, h, p, n), np.float32)
    for t in range(s):
        for hh in range(h):
            gi = hh // hg
            a = np.exp(log_a[:, t, hh])
            state[:, hh] = (state[:, hh] * a[:, None, None]
                            + xdt[:, t, hh][:, :, None] * B[:, t, gi][:, None, :])
            y[:, t, hh] = np.einsum("bpn,bn->bp", state[:, hh], C[:, t, gi])
    return y, state


def _rand_ssd(rng, b=2, s=24, h=4, p=8, g=2, n=16):
    xdt = rng.randn(b, s, h, p).astype(np.float32) * 0.5
    log_a = -np.abs(rng.randn(b, s, h)).astype(np.float32) * 0.3
    B = rng.randn(b, s, g, n).astype(np.float32) * 0.3
    C = rng.randn(b, s, g, n).astype(np.float32) * 0.3
    return xdt, log_a, B, C


@pytest.mark.parametrize("chunk", [4, 8, 24])
def test_ssd_chunked_matches_naive(chunk, rng):
    xdt, log_a, B, C = _rand_ssd(rng)
    y_ref, st_ref = _naive_ssd(xdt, log_a, B, C)
    y, st = _ssd_chunked(jnp.asarray(xdt), jnp.asarray(log_a),
                         jnp.asarray(B), jnp.asarray(C), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st).reshape(st_ref.shape), st_ref,
                               rtol=2e-5, atol=2e-5)


def test_ssd_non_divisible_seq_padding(rng):
    xdt, log_a, B, C = _rand_ssd(rng, s=13)
    y_ref, st_ref = _naive_ssd(xdt, log_a, B, C)
    y, st = _ssd_chunked(jnp.asarray(xdt), jnp.asarray(log_a),
                         jnp.asarray(B), jnp.asarray(C), chunk=8)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st).reshape(st_ref.shape), st_ref,
                               rtol=2e-5, atol=2e-5)


def test_ssd_initial_state_continuity(rng):
    """Running [0:s1] then [s1:] with the carried state == running [0:s]."""
    xdt, log_a, B, C = _rand_ssd(rng, s=32)
    j = lambda t: jnp.asarray(t)
    y_full, st_full = _ssd_chunked(j(xdt), j(log_a), j(B), j(C), chunk=8)
    s1 = 16
    y1, st1 = _ssd_chunked(j(xdt[:, :s1]), j(log_a[:, :s1]), j(B[:, :s1]),
                           j(C[:, :s1]), chunk=8)
    y2, st2 = _ssd_chunked(j(xdt[:, s1:]), j(log_a[:, s1:]), j(B[:, s1:]),
                           j(C[:, s1:]), chunk=8, initial_state=st1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, s1:]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=2e-5, atol=2e-5)


@given(s=st.integers(2, 40), w=st.sampled_from([4, 16]))
@settings(max_examples=10, deadline=None)
def test_linear_recurrence_property(s, w):
    r = np.random.RandomState(s * 13 + w)
    a = jnp.asarray(np.exp(-np.abs(r.randn(2, s, w))).astype(np.float32))
    b = jnp.asarray(r.randn(2, s, w).astype(np.float32))
    h = np.asarray(_scan_linear_recurrence(a, b))
    hh = np.zeros((2, w), np.float32)
    for t in range(s):
        hh = np.asarray(a)[:, t] * hh + np.asarray(b)[:, t]
        np.testing.assert_allclose(h[:, t], hh, rtol=3e-5, atol=3e-5)


def test_linear_recurrence_with_initial_state(rng):
    a = jnp.asarray(np.exp(-np.abs(rng.randn(1, 8, 4))).astype(np.float32))
    b = jnp.asarray(rng.randn(1, 8, 4).astype(np.float32))
    h0 = jnp.asarray(rng.randn(1, 4).astype(np.float32))
    h = _scan_linear_recurrence(a, b, h0)
    hh = np.asarray(h0).copy()
    for t in range(8):
        hh = np.asarray(a)[:, t] * hh + np.asarray(b)[:, t]
    np.testing.assert_allclose(np.asarray(h[:, -1]), hh, rtol=3e-5, atol=3e-5)
