"""Zero-warmup serving: the persistent AOT compile cache.

Acceptance criteria (ISSUE 7):
  * a FRESH engine constructed over a warm cache directory answers its
    first serving-path request with ZERO jit compiles (trace counters),
    bit-identical to the uncached jit path;
  * corrupted cache entries degrade to a jit compile with a warning —
    never a crash;
  * ``serve_report()`` carries per-key cold/warm hit rates;
  * ``prewarm(targets=...)`` / the explorer's frontier hook warm a list of
    design points ahead of traffic;
  * the LM decode path gets the same guarantee through its keyed decoders.
"""

import pickle

import jax
import numpy as np
import pytest

from repro.autotune import DesignTarget, SpaceSpec
from repro.autotune.explorer import explore
from repro.config import FixedPointConfig
from repro.kernels.schedule import KernelSchedule, cache_meta, schedule_key
from repro.models import build_model
from repro.registry import get_config
from repro.serving import CompileCache, LMServingEngine, RNNServingEngine
from repro.testing import tiny_config

SCHED = KernelSchedule(reuse_factor=2, mode="static", block_batch=4,
                       backend="pallas_interpret")


@pytest.fixture(scope="module")
def gru_tagger():
    cfg = get_config("top-tagging-gru")
    return cfg, build_model(cfg).init(jax.random.PRNGKey(0))


def _engine(gru_tagger, cache_dir=None, **kw):
    cfg, params = gru_tagger
    kw.setdefault("max_batch", 4)
    return RNNServingEngine(cfg, params, cache_dir=cache_dir, **kw)


def _serve_once(eng, x):
    """One serving-path round trip (submit -> padded flush), batch rows."""
    reqs = [eng.submit(x[i], schedule=SCHED) for i in range(x.shape[0])]
    eng.flush(force=True)
    return np.stack([r.result for r in reqs])


# ---------------------------------------------------------------------------
# Cold -> warm round trip (the PR's acceptance criterion)
# ---------------------------------------------------------------------------


def test_cold_then_warm_engine_zero_compiles_bit_identical(gru_tagger,
                                                           tmp_path, rng):
    key = schedule_key(SCHED)
    x = rng.randn(4, 20, 6).astype(np.float32)

    cold = _engine(gru_tagger, cache_dir=tmp_path)
    got_cold = _serve_once(cold, x)
    assert cold.trace_count(key) == 1                 # first process compiles
    row = cold.serve_report()[key]
    assert row["compile"]["cold"] == 1
    assert row["compile"]["warm"] == 0
    assert row["compile"]["first_compile_s"] > 0
    assert list(tmp_path.glob("*.jaxcache"))          # artifact on disk
    assert not list(tmp_path.glob("*.tmp.*"))         # rename left no temp

    # a FRESH engine over the same cache dir: first request, zero compiles
    warm = _engine(gru_tagger, cache_dir=tmp_path)
    got_warm = _serve_once(warm, x)
    assert warm.trace_count(key) == 0                 # ZERO jit compiles
    assert warm.compile_cache.cold_compiles == 0
    row = warm.serve_report()[key]
    assert row["compile"]["warm"] == 1
    assert row["compile"]["hit_rate"] == 1.0

    # bit-identical to the uncached jit path
    ref = _engine(gru_tagger)                         # no cache_dir: plain jit
    got_jit = _serve_once(ref, x)
    np.testing.assert_array_equal(got_warm, got_jit)
    np.testing.assert_array_equal(got_cold, got_jit)


def test_corrupted_cache_entry_falls_back_to_jit(gru_tagger, tmp_path, rng):
    x = rng.randn(4, 20, 6).astype(np.float32)
    key = schedule_key(SCHED)
    want = _serve_once(_engine(gru_tagger, cache_dir=tmp_path), x)
    entries = list(tmp_path.glob("*.jaxcache"))
    assert entries
    for p in entries:                                  # corrupt every entry
        p.write_bytes(b"not a serialized executable")

    eng = _engine(gru_tagger, cache_dir=tmp_path)
    with pytest.warns(RuntimeWarning, match="falling back to jit"):
        got = _serve_once(eng, x)
    np.testing.assert_array_equal(got, want)           # served correctly
    assert eng.trace_count(key) == 1                   # via a cold compile
    assert eng.serve_report()[key]["compile"]["errors"] >= 1


def test_stale_metadata_is_never_served(gru_tagger, tmp_path, rng):
    """An entry whose stored metadata disagrees with the expected content
    hash (e.g. a colliding filename from another toolchain) is rejected."""
    x = rng.randn(4, 20, 6).astype(np.float32)
    _serve_once(_engine(gru_tagger, cache_dir=tmp_path), x)
    entry = next(iter(tmp_path.glob("*.jaxcache")))
    doc = pickle.loads(entry.read_bytes())
    doc["meta"] = {**doc["meta"], "jaxlib": "0.0.0"}   # stale toolchain
    entry.write_bytes(pickle.dumps(doc))
    eng = _engine(gru_tagger, cache_dir=tmp_path)
    with pytest.warns(RuntimeWarning, match="unusable"):
        _serve_once(eng, x)
    assert eng.trace_count(schedule_key(SCHED)) == 1   # recompiled


def test_distinct_schedule_fp_shape_get_distinct_entries(gru_tagger,
                                                         tmp_path, rng):
    """The content hash separates schedule, fp, and shape-bucket axes — a
    warm hit can never hand back another design point's executable."""
    eng = _engine(gru_tagger, cache_dir=tmp_path)
    x = rng.randn(4, 20, 6).astype(np.float32)
    fp = FixedPointConfig(16, 6)
    _serve_once(eng, x)                                    # (SCHED, float)
    n1 = len(list(tmp_path.glob("*.jaxcache")))
    reqs = [eng.submit(x[i], schedule=SCHED, fp=fp) for i in range(4)]
    eng.flush(force=True)                                  # (SCHED, ap16_6)
    assert all(r.result is not None for r in reqs)
    n2 = len(list(tmp_path.glob("*.jaxcache")))
    assert n2 == n1 + 1
    # same key, different shape bucket (a different max_batch replica)
    other = _engine(gru_tagger, cache_dir=tmp_path, max_batch=2)
    _serve_once(other, x[:2])
    assert len(list(tmp_path.glob("*.jaxcache"))) == n2 + 1
    assert other.trace_count(schedule_key(SCHED)) == 1     # cold, not stale


def test_cache_meta_is_exhaustive_over_schedule_axes():
    """Every schedule dataclass field lands in the persistent-cache
    identity, so a future axis invalidates entries instead of sharing."""
    import dataclasses

    base = cache_meta(SCHED, None)["schedule"]
    assert set(base) == {f.name for f in dataclasses.fields(KernelSchedule)}
    assert cache_meta(SCHED, None) != cache_meta(SCHED.replace(ii=0,
                                                 mode="pipeline"), None)
    assert (cache_meta(SCHED, FixedPointConfig(16, 6))
            != cache_meta(SCHED, FixedPointConfig(8, 3)))


# ---------------------------------------------------------------------------
# Pre-warm APIs
# ---------------------------------------------------------------------------


def test_prewarm_targets_then_fresh_engine_serves_warm(gru_tagger, tmp_path,
                                                       rng):
    targets = [DesignTarget(max_dsp=600), DesignTarget(objective="latency")]
    eng = _engine(gru_tagger, cache_dir=tmp_path)
    report = eng.prewarm(targets=targets)
    assert report and all(r["status"] == "cold" for r in report.values())
    keys = list(report)

    fresh = _engine(gru_tagger, cache_dir=tmp_path)
    report2 = fresh.prewarm(targets=targets)
    assert [r["status"] for r in report2.values()] == ["warm"] * len(keys)
    assert fresh.compile_cache.cold_compiles == 0
    # first real request on a prewarmed queue: zero compiles, correct result
    x = rng.randn(3, 20, 6).astype(np.float32)
    pt = fresh.schedule_for_target(targets[0])
    reqs = [fresh.submit(x[i], target=targets[0]) for i in range(3)]
    fresh.flush(force=True)
    assert fresh.trace_count(pt.key) == 0
    ref = _engine(gru_tagger)
    want = ref.predict(x, schedule=pt.schedule, fp=pt.fp)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.result), want[i])


def test_auto_schedule_warms_selected_point(gru_tagger, tmp_path):
    spec = SpaceSpec(backends=("pallas_interpret",), block_batches=(4,))
    eng = _engine(gru_tagger, cache_dir=tmp_path)
    pt = eng.auto_schedule(DesignTarget(max_dsp=600), spec=spec)  # warmup=True
    assert eng.compile_cache.stats(pt.key).cold == 1
    fresh = _engine(gru_tagger, cache_dir=tmp_path)
    fresh.auto_schedule(DesignTarget(max_dsp=600), spec=spec)
    assert fresh.compile_cache.cold_compiles == 0      # warm start
    assert fresh.trace_count(pt.key) == 0


def test_exploration_prewarm_hook(gru_tagger, tmp_path):
    cfg, _ = gru_tagger
    spec = SpaceSpec(backends=("xla",), block_batches=(4,))
    ex = explore(cfg, DesignTarget(objective="latency"), spec)
    eng = _engine(gru_tagger, cache_dir=tmp_path)
    report = ex.prewarm(eng, k=2)
    assert len(report) == min(2, len(ex.feasible))
    assert all(r["status"] == "cold" for r in report.values())
    fresh = _engine(gru_tagger, cache_dir=tmp_path)
    assert all(r["status"] == "warm"
               for r in ex.prewarm(fresh, k=2).values())


def test_warmup_without_cache_dir_still_works(gru_tagger, rng):
    """cache_dir=None keeps the old in-process behavior: warmup compiles the
    serving bucket once, the flush path reuses it (no disk involved)."""
    eng = _engine(gru_tagger)
    out = eng.warmup(schedule=SCHED)
    key = schedule_key(SCHED)
    assert out[key]["status"] == "cold"
    assert eng.trace_count(key) == 1
    x = rng.randn(4, 20, 6).astype(np.float32)
    _serve_once(eng, x)
    assert eng.trace_count(key) == 1                   # no second compile


# ---------------------------------------------------------------------------
# LM decode path
# ---------------------------------------------------------------------------


def test_lm_engine_cold_then_warm_decode(tmp_path):
    cfg = tiny_config(get_config("stablelm-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    cold = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                           cache_dir=tmp_path)
    a = cold.add_request([3, 4, 5], max_new=2)
    done = cold.run_to_completion()
    assert cold.trace_count("default") == 1
    assert cold.serve_report()["default"]["compile"]["cold"] == 1

    warm = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                           cache_dir=tmp_path)
    b = warm.add_request([3, 4, 5], max_new=2)
    done2 = warm.run_to_completion()
    assert warm.trace_count("default") == 0            # ZERO decode compiles
    assert done2[b] == done[a]                         # same greedy tokens
    row = warm.serve_report()["default"]
    assert row["compile"]["warm"] == 1 and row["compile"]["cold"] == 0

    # and bit-identical to a never-cached engine
    ref = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
    c = ref.add_request([3, 4, 5], max_new=2)
    assert ref.run_to_completion()[c] == done[a]


def test_lm_prewarm_keyed_schedule(tmp_path):
    cfg = tiny_config(get_config("stablelm-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    sched = KernelSchedule(reuse_factor=2, mode="static")
    eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                          cache_dir=tmp_path)
    rep = eng.prewarm(schedules=[sched])
    assert rep[schedule_key(sched)]["status"] == "cold"
    fresh = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                            cache_dir=tmp_path)
    rep2 = fresh.prewarm(schedules=[sched])
    assert rep2[schedule_key(sched)]["status"] == "warm"
    rid = fresh.add_request([5, 7], max_new=2, schedule=sched)
    out = fresh.run_to_completion()
    assert fresh.trace_count(schedule_key(sched)) == 0
    ref = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
    r2 = ref.add_request([5, 7], max_new=2, schedule=sched)
    assert ref.run_to_completion()[r2] == out[rid]


# ---------------------------------------------------------------------------
# CompileCache unit behavior
# ---------------------------------------------------------------------------


def test_compile_cache_disabled_counts_cold_compiles():
    cache = CompileCache(None)
    assert not cache.enabled
    assert cache.load("x", {"k": 1}, "key") is None
    assert cache.store("x", {"k": 1}, object(), "key") is False
    cache.record_cold("key", 0.5)
    cache.record_warm("key")
    row = cache.report_row("key")
    assert row["cold"] == 1 and row["warm"] == 1 and row["hit_rate"] == 0.5
    assert row["first_compile_s"] == 0.5


def test_compile_cache_store_is_atomic_and_concurrent_safe(tmp_path):
    """Two caches (two replicas) storing the same entry: both succeed, one
    complete file remains, no temp litter — the write-temp-then-rename
    contract N workers sharing a directory rely on."""
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2)
    compiled = f.lower(jnp.ones((2,))).compile()
    meta = {"kind": "unit"}
    a, b = CompileCache(tmp_path), CompileCache(tmp_path)
    assert a.store("e", meta, compiled, "k")
    assert b.store("e", meta, compiled, "k")
    files = list(tmp_path.iterdir())
    assert len(files) == 1 and files[0].suffix == ".jaxcache"
    fn = a.load("e", meta, "k")
    assert fn is not None
    np.testing.assert_array_equal(np.asarray(fn(jnp.ones((2,)))), [2.0, 2.0])


# ---------------------------------------------------------------------------
# Quarantine of known-corrupt entries (ISSUE 8)
# ---------------------------------------------------------------------------


def test_corrupt_entry_quarantined_warns_once(tmp_path):
    """Regression (ISSUE 8): a known-corrupt entry was re-read, re-unpickled
    and re-warned on EVERY request.  The first failure warns and quarantines
    the fingerprint; later lookups skip the file silently."""
    import warnings

    cache = CompileCache(tmp_path)
    meta = {"kind": "t"}
    cache.entry_path("e", meta).write_bytes(b"\x00garbage\x00")

    with pytest.warns(RuntimeWarning, match="quarantined"):
        assert cache.load("e", meta, "k") is None      # first: warn + mark
    with warnings.catch_warnings():
        warnings.simplefilter("error")                 # any warning fails
        assert cache.load("e", meta, "k") is None      # later: silent skip
        assert cache.load("e", meta, "k") is None
    st = cache.stats("k")
    assert st.errors == 1                              # ONE failed attempt
    assert st.quarantined == 2                         # skips counted
    assert st.summary()["quarantined"] == 2.0


def test_successful_store_lifts_quarantine(gru_tagger, tmp_path, rng):
    """A fresh, complete entry written over a quarantined path is served
    again — the quarantine names the corrupt bytes, not the fingerprint
    forever."""
    x = rng.randn(4, 20, 6).astype(np.float32)
    key = schedule_key(SCHED)
    want = _serve_once(_engine(gru_tagger, cache_dir=tmp_path), x)
    for p in tmp_path.glob("*.jaxcache"):
        p.write_bytes(b"rotten")

    eng = _engine(gru_tagger, cache_dir=tmp_path)
    with pytest.warns(RuntimeWarning, match="quarantined"):
        got = _serve_once(eng, x)                      # cold compile + store
    np.testing.assert_array_equal(got, want)
    assert eng.trace_count(key) == 1
    assert not eng.compile_cache._quarantine           # store lifted it

    fresh = _engine(gru_tagger, cache_dir=tmp_path)    # overwritten entry
    got2 = _serve_once(fresh, x)                       # serves warm again
    assert fresh.trace_count(key) == 0
    assert fresh.compile_cache.cold_compiles == 0
    np.testing.assert_array_equal(got2, want)
