"""Decode-path correctness: token-by-token decode must reproduce the full
forward pass for every family (the serving engine's foundation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models import transformer as tf
from repro.models.decode import cache_specs, decode_step
from repro.registry import get_config
from repro.testing import tiny_config

FAMS = ["stablelm-3b", "gemma-2b", "mamba2-780m", "recurrentgemma-9b",
        "qwen3-moe-30b-a3b"]


def _decode_vs_forward(cfg, S=12, B=2, tol=5e-4):
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    hidden, _ = tf.forward(cfg, params, toks, train=False)
    logits_full = np.asarray(tf.logits_fn(cfg, params, hidden))
    specs = cache_specs(cfg, B, S + 4, "float32")
    cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
             for k, s in specs.items()}
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    errs = []
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        errs.append(float(np.abs(np.asarray(logits[:, 0])
                                 - logits_full[:, t]).max()))
    assert max(errs) < tol, f"{cfg.name}: {max(errs)}"


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = tiny_config(get_config(arch))
    if cfg.moe is not None:
        # remove capacity truncation so decode/forward see the same experts
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0, eval_capacity_factor=8.0))
    _decode_vs_forward(cfg)


def test_decode_local_attention_window_ring_buffer():
    """Griffin local attention through the ring buffer, past the window."""
    cfg = tiny_config(get_config("recurrentgemma-9b"))
    cfg = cfg.replace(rglru=dataclasses.replace(cfg.rglru, window=8))
    _decode_vs_forward(cfg, S=20, tol=1e-3)


def test_staggered_positions_decode():
    """Different sequences at different positions (continuous batching)."""
    cfg = tiny_config(get_config("stablelm-3b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    hidden, _ = tf.forward(cfg, params, toks, train=False)
    logits_full = np.asarray(tf.logits_fn(cfg, params, hidden))

    specs = cache_specs(cfg, B, S + 2, "float32")
    cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
             for k, s in specs.items()}
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    # seq 0 starts 3 ticks late; feed dummy token, its cache rows stay
    # correct because updates are position-indexed per sequence
    for t in range(S + 3):
        pos = jnp.asarray([min(t, S - 1), max(t - 3, 0)], jnp.int32)
        tok = jnp.stack([toks[0, min(t, S - 1)],
                         toks[1, max(t - 3, 0)]])[:, None]
        logits, cache = step(params, cache, tok, pos)
        if t >= 3:
            err = np.abs(np.asarray(logits[1, 0])
                         - logits_full[1, t - 3]).max()
            assert err < 5e-4, (t, err)


def test_whisper_decode_with_cross_attention():
    cfg = tiny_config(get_config("whisper-medium"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S_txt, S_enc = 2, 8, 16
    frames = jnp.asarray(
        np.random.RandomState(0).randn(B, S_enc, cfg.d_model)
        .astype(np.float32) * 0.05)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_txt), 0,
                              cfg.vocab_size)
    hidden, _ = tf.forward(cfg, params, toks, train=False,
                           frame_embeds=frames)
    logits_full = np.asarray(tf.logits_fn(cfg, params, hidden))

    # precompute encoder + cross kv into the cache
    enc = tf._encode(cfg, params, frames)
    specs = cache_specs(cfg, B, S_txt + 2, "float32")
    cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
             for k, s in specs.items()}
    xk, xv = [], []
    for l in range(cfg.n_decoder_layers):
        p_l = {k: v[l] for k, v in tf.slice_layer(params, "xdecoder/").items()}
        k = jnp.einsum("bsd,dhk->bshk", enc,
                       p_l["xdecoder/xattn/wk"].astype(enc.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc,
                       p_l["xdecoder/xattn/wv"].astype(enc.dtype))
        xk.append(k)
        xv.append(v)
    cache["cache/xk"] = jnp.stack(xk)
    cache["cache/xv"] = jnp.stack(xv)

    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    errs = []
    for t in range(S_txt):
        logits, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        errs.append(float(np.abs(np.asarray(logits[:, 0])
                                 - logits_full[:, t]).max()))
    assert max(errs) < 5e-4, max(errs)
