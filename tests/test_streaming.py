"""Trigger-grade streaming: admission control, shedding, degradation, chaos.

Acceptance criteria (ISSUE 8):
  * every stage boundary gets a monotone timestamp; the per-stage budget
    report renders beside ``serve_report``;
  * admission is token-bucketed at the priced throughput of the resolved
    design point (``core.hls.admission_rate_eps``) — a <=1x replay never
    sheds, a 2x replay sheds and/or downgrades, with exact per-key
    accounting (``submitted == answered + shed + failed``, nothing silent);
  * answered requests meet their deadline even under injected stalls (the
    dispatch-time re-check converts would-be misses into late sheds);
  * the degradation ladder downgrades under sustained high-water queue
    depth and recovers at low-water, over pre-warmed rungs only;
  * non-degraded (rung 0) outputs are bit-identical to direct ``predict``;
  * the fault matrix — stage stalls, flush exceptions, corrupted compile
    cache entries, backwards clock steps — passes without deadlock or
    silent request loss.
"""

import warnings

import jax
import numpy as np
import pytest

from repro.autotune import (DesignTarget, SpaceSpec, degradation_ladder,
                            select)
from repro.core.hls import admission_rate_eps, price_point
from repro.models import build_model
from repro.registry import get_config
from repro.serving import (FaultInjector, InjectedFault, RNNServingEngine,
                           StreamingPipeline, VirtualClock,
                           format_stream_report)
from repro.serving.faults import break_engine_key, corrupt_cache_entries
from repro.serving.streaming import STAGES, TokenBucket
from repro.testing import native_fp_configs

SPEC = SpaceSpec(backends=("xla",), block_batches=(8,))
CLOCK_MHZ = 200.0


@pytest.fixture(scope="module")
def gru_tagger():
    cfg = get_config("top-tagging-gru")
    return cfg, build_model(cfg).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def ladder(gru_tagger):
    """Base rung = the latency-best point under a DSP budget (R4); the
    degraded rungs walk the frontier down-R toward higher priced
    throughput (R2, R1)."""
    cfg, _ = gru_tagger
    base = select(cfg, DesignTarget(max_dsp=400, objective="latency"), SPEC)
    rungs = degradation_ladder(cfg, base, spec=SPEC, max_rungs=3)
    assert len(rungs) == 3
    return rungs


@pytest.fixture(scope="module")
def engine(gru_tagger):
    cfg, params = gru_tagger
    return RNNServingEngine(cfg, params, max_batch=8)


def _events(cfg, n, seed=0):
    r = cfg.rnn
    return np.random.RandomState(seed).randn(
        n, r.seq_len, r.input_size).astype(np.float32)


def _pipe(engine, ladder, clk, **kw):
    kw.setdefault("deadline_us", 50.0)
    kw.setdefault("prewarm", False)     # keys still registered; compiles
    kw.setdefault("clock_mhz", CLOCK_MHZ)  # happen lazily to keep CI fast
    return StreamingPipeline(engine, ladder, clock=clk, **kw)


def _replay(pipe, clk, xs, rate_mult, *, base_rate=None):
    """Deterministic arrival trace at ``rate_mult`` x the rung-0 priced
    throughput; push + pump per tick, then drain."""
    rate = base_rate if base_rate is not None else pipe._rung_rate(0)
    dt = 1.0 / (rate_mult * rate)
    reqs = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i, x in enumerate(xs):
            t = clk.advance(dt) if i else clk.t
            reqs.append(pipe.push(x, now=t))
            pipe.pump(now=t)
        pipe.drain()
    return reqs


# ---------------------------------------------------------------------------
# Stage stamps & budget report
# ---------------------------------------------------------------------------


def test_stage_stamps_monotone_and_complete(engine, ladder):
    cfg = engine.cfg
    clk = VirtualClock()
    pipe = _pipe(engine, ladder, clk)
    reqs = _replay(pipe, clk, _events(cfg, 40), 1.0)
    assert all(r.status == "answered" for r in reqs)
    for r in reqs:
        times = [r.arrival_s] + [r.stamps[s] for s in STAGES]
        assert all(a <= b + 1e-15 for a, b in zip(times, times[1:])), \
            (r.req_id, r.stamps)
        assert r.latency_s is not None and r.latency_s >= 0


def test_stage_budget_report_counts_overruns(engine, ladder):
    cfg = engine.cfg
    clk = VirtualClock()
    faults = FaultInjector().stall("prep", 5e-6, times=3)
    pipe = _pipe(engine, ladder, clk, faults=faults,
                 stage_budgets_us={"prep": 1.0})
    _replay(pipe, clk, _events(cfg, 20), 0.5)
    rep = pipe.stage_report()
    assert set(rep) == set(STAGES)
    assert rep["prep"]["budget_us"] == 1.0
    assert rep["prep"]["over_budget"] == 3          # exactly the stalled ones
    assert rep["infer"]["over_budget"] == 0
    for stage in STAGES:
        assert rep[stage]["sim"]["served"] == 20


def test_format_stream_report_renders_beside_serve_report(engine, ladder):
    cfg = engine.cfg
    clk = VirtualClock()
    pipe = _pipe(engine, ladder, clk)
    _replay(pipe, clk, _events(cfg, 16), 1.0)
    text = format_stream_report(pipe)
    for stage in STAGES:
        assert stage in text
    assert "ladder" in text
    assert "schedule key" in text                   # the serve_report table
    assert ladder[0].key in text


# ---------------------------------------------------------------------------
# Admission control & shedding
# ---------------------------------------------------------------------------


def test_no_shed_at_or_below_priced_throughput(engine, ladder):
    """A replay at exactly the priced admission rate (and below) must not
    shed — the acceptance criterion the bench gate enforces."""
    cfg = engine.cfg
    for mult in (0.5, 1.0):
        clk = VirtualClock()
        pipe = _pipe(engine, ladder[:1], clk)       # single rung: no escape
        reqs = _replay(pipe, clk, _events(cfg, 200), mult)
        acc = pipe.verify_accounting()
        (key,) = acc
        assert acc[key]["shed"] == 0, (mult, acc)
        assert acc[key]["answered"] == 200
        assert all(r.stamps["infer"] <= r.deadline_s + 1e-12 for r in reqs)


def test_admission_sheds_at_2x_single_rung(engine, ladder):
    """With no ladder to climb, a 2x replay must shed ~half at admission —
    counted per key, never silently dropped."""
    cfg = engine.cfg
    clk = VirtualClock()
    pipe = _pipe(engine, ladder[:1], clk)
    reqs = _replay(pipe, clk, _events(cfg, 400), 2.0)
    acc = pipe.verify_accounting()[ladder[0].key]
    assert acc["shed_admission"] > 100              # ~185 of 400
    assert acc["answered"] + acc["shed"] + acc["failed"] == 400
    statuses = {r.status for r in reqs}
    assert statuses <= {"answered", "shed"}
    # answered requests still meet the deadline
    for r in reqs:
        if r.status == "answered":
            assert r.stamps["infer"] <= r.deadline_s + 1e-12


def test_deadline_shed_at_enqueue_when_budget_cannot_cover(engine, ladder):
    """A deadline below the rung's service latency sheds at enqueue —
    before the request wastes a server slot."""
    cfg = engine.cfg
    clk = VirtualClock()
    svc_us = ladder[0].estimate.service_s(CLOCK_MHZ) * 1e6
    pipe = _pipe(engine, ladder[:1], clk, deadline_us=svc_us * 0.5)
    reqs = _replay(pipe, clk, _events(cfg, 10), 0.25)
    acc = pipe.verify_accounting()[ladder[0].key]
    assert acc["shed_deadline"] == 10
    assert acc["answered"] == 0
    assert all(r.status == "shed" and r.shed_reason == "deadline"
               for r in reqs)


def test_queue_full_shed_is_explicit(engine, ladder):
    """A bounded queue rejects at enqueue with its own counter — the queue
    never grows past ``max_queue``."""
    cfg = engine.cfg
    clk = VirtualClock()
    pipe = _pipe(engine, ladder[:1], clk, max_queue=3, burst=64.0,
                 deadline_us=10_000.0, high_water=100)
    xs = _events(cfg, 10)
    reqs = [pipe.push(x, now=clk.t) for x in xs]    # no pump in between
    assert pipe.in_flight() == 3
    acc = pipe.verify_accounting()[ladder[0].key]
    assert acc["shed_queue_full"] == 7
    pipe.drain()
    acc = pipe.verify_accounting()[ladder[0].key]
    assert acc["answered"] == 3
    assert sum(1 for r in reqs if r.shed_reason == "queue_full") == 7


def test_admission_rate_bridge(gru_tagger, ladder):
    """The pipeline's token-bucket rate IS the priced throughput of the
    resolved design point, through ``core.hls.admission_rate_eps``."""
    base = ladder[0]
    assert admission_rate_eps(base.estimate, CLOCK_MHZ) \
        == pytest.approx(base.throughput_eps(CLOCK_MHZ))
    assert admission_rate_eps(base.estimate, CLOCK_MHZ, utilization=0.5) \
        == pytest.approx(0.5 * base.throughput_eps(CLOCK_MHZ))
    with pytest.raises(ValueError):
        admission_rate_eps(base.estimate, CLOCK_MHZ, utilization=0.0)
    with pytest.raises(ValueError):
        admission_rate_eps(base.estimate, CLOCK_MHZ, utilization=1.5)


def test_token_bucket_exact_rate_never_starves():
    tb = TokenBucket(rate_eps=1e6, burst=4.0)
    dt = 1.0 / 1e6
    t = 0.0
    for _ in range(10_000):                         # 1.0x: float-rounding
        assert tb.try_take(t)                       # noise only, burst absorbs
        t += dt
    tb2 = TokenBucket(rate_eps=1e6, burst=4.0)
    taken = sum(tb2.try_take(i * dt / 2) for i in range(1000))
    assert 500 <= taken <= 520                      # 2.0x: ~half admitted


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


def test_degradation_ladder_ascending_throughput(gru_tagger, ladder):
    cfg, _ = gru_tagger
    eps = [p.throughput_eps(CLOCK_MHZ) for p in ladder]
    assert eps == sorted(eps)
    assert len(set(eps)) == len(eps)                # strictly ascending
    for a, b in zip(eps, eps[1:]):
        assert b >= 1.5 * a                         # default min_gain
    # native-int candidates merge in when fp is native
    fp8 = native_fp_configs()["int8"]
    rungs8 = degradation_ladder(cfg, ladder[0], spec=SPEC, fp=fp8,
                                max_rungs=4)
    eps8 = [p.throughput_eps(CLOCK_MHZ) for p in rungs8]
    assert eps8 == sorted(eps8) and len(set(eps8)) == len(eps8)
    with pytest.raises(ValueError):
        degradation_ladder(cfg, ladder[0], spec=SPEC, max_rungs=0)
    with pytest.raises(ValueError):
        degradation_ladder(cfg, ladder[0], spec=SPEC, min_gain=1.0)


def test_ladder_must_be_strictly_ascending(engine, ladder):
    with pytest.raises(ValueError, match="ascending"):
        StreamingPipeline(engine, tuple(reversed(ladder)), deadline_us=50.0,
                          prewarm=False)


def test_downgrade_at_high_water_and_recover_at_low_water(engine, ladder):
    """2x overload drives the rung down the ladder (admission rate rises);
    returning to 0.5x recovers to rung 0."""
    cfg = engine.cfg
    clk = VirtualClock()
    pipe = _pipe(engine, ladder, clk)
    base_rate = pipe._rung_rate(0)
    _replay(pipe, clk, _events(cfg, 300), 2.0, base_rate=base_rate)
    assert pipe.downgrades >= 1
    assert pipe.rung >= 1
    assert pipe.admission_rate() > base_rate        # rate follows the rung
    _replay(pipe, clk, _events(cfg, 400, seed=1), 0.5, base_rate=base_rate)
    assert pipe.recoveries >= 1
    assert pipe.rung == 0
    assert pipe.admission_rate() == pytest.approx(base_rate)
    pipe.verify_accounting()


def test_all_rungs_prewarmed_at_construction(gru_tagger, ladder):
    """Every rung's executable exists before traffic — a downgrade under
    overload never pays a compile."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=8)
    StreamingPipeline(eng, ladder, deadline_us=50.0, prewarm=True)
    for pt in ladder:
        assert pt.key in eng._infer_cache
        assert eng._infer_cache[pt.key].compiled_signatures() >= 1


def test_rung0_outputs_bit_identical_to_direct_predict(engine, ladder):
    cfg = engine.cfg
    clk = VirtualClock()
    pipe = _pipe(engine, ladder, clk)
    xs = _events(cfg, 24, seed=3)
    reqs = _replay(pipe, clk, xs, 1.0)
    assert all(r.status == "answered" and r.rung == 0 for r in reqs)
    want = engine.predict(xs, schedule=ladder[0].schedule, fp=ladder[0].fp)
    got = np.stack([r.result for r in reqs])
    np.testing.assert_array_equal(got, want)


def test_exec_mode_one_matches_batch(engine, ladder):
    cfg = engine.cfg
    xs = _events(cfg, 6, seed=4)
    outs = {}
    for mode in ("batch", "one"):
        clk = VirtualClock()
        pipe = _pipe(engine, ladder, clk, exec_mode=mode)
        reqs = _replay(pipe, clk, xs, 0.5)
        assert all(r.status == "answered" for r in reqs)
        outs[mode] = np.stack([r.result for r in reqs])
    np.testing.assert_array_equal(outs["batch"], outs["one"])


# ---------------------------------------------------------------------------
# Fault matrix (chaos suite) — no deadlock, no silent loss
# ---------------------------------------------------------------------------


def test_chaos_infer_stall_within_headroom_sheds_nothing(engine, ladder):
    """A stall the deadline headroom can absorb degrades latency, not
    outcomes: everything still answered, still within deadline."""
    cfg = engine.cfg
    clk = VirtualClock()
    faults = FaultInjector().stall("infer", 40e-6, after=5)   # < 50us budget
    pipe = _pipe(engine, ladder[:1], clk, faults=faults)
    reqs = _replay(pipe, clk, _events(cfg, 60), 1.0)
    acc = pipe.verify_accounting()[ladder[0].key]
    assert acc["answered"] == 60
    assert acc["deadline_miss"] == 0
    assert max(r.infer_latency_s for r in reqs) > 30e-6       # stall visible
    for r in reqs:
        assert r.stamps["infer"] <= r.deadline_s + 1e-12


def test_chaos_infer_stall_never_breaks_deadline_for_answered(engine, ladder):
    """A stall LONGER than the deadline extends the server-free pointer
    before the dispatch-time re-check: queued victims shed late, arrivals
    inside the outage window shed at enqueue, and every ANSWERED request
    still meets its deadline."""
    cfg = engine.cfg
    clk = VirtualClock(1.0)
    faults = FaultInjector().stall("infer", 60e-6)            # > 50us budget
    pipe = _pipe(engine, ladder[:1], clk, faults=faults, burst=32.0)
    xs = _events(cfg, 60)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        reqs = [pipe.push(x, now=clk.t) for x in xs[:10]]     # queued burst
        late = pipe.pump(now=clk.t)                           # stall fires
        assert all(r.status == "shed" and r.shed_reason == "deadline"
                   for r in late)                             # late sheds
        assert len(late) == 10
        reqs += _replay(pipe, clk, xs[10:], 1.0)
    acc = pipe.verify_accounting()[ladder[0].key]
    assert acc["shed_deadline"] >= 10
    assert acc["answered"] > 0                                # recovered
    assert acc["deadline_miss"] == 0
    for r in reqs:
        if r.status == "answered":
            assert r.stamps["infer"] <= r.deadline_s + 1e-12


def test_chaos_stage_failure_fails_only_that_request(engine, ladder):
    cfg = engine.cfg
    clk = VirtualClock()
    faults = FaultInjector().fail("prep", after=3, times=2)
    pipe = _pipe(engine, ladder, clk, faults=faults)
    reqs = _replay(pipe, clk, _events(cfg, 20), 0.5)
    failed = [r for r in reqs if r.status == "failed"]
    assert len(failed) == 2
    assert all(isinstance(r.error, InjectedFault) for r in failed)
    assert sum(1 for r in reqs if r.status == "answered") == 18
    pipe.verify_accounting()


def test_chaos_flush_exception_fails_batch_with_error_attached(gru_tagger,
                                                               ladder):
    """An exception inside the compiled infer fn surfaces per-request via
    the batcher's isolation — the pipeline reports those requests failed
    (error attached) and keeps serving afterwards."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=8)
    clk = VirtualClock()
    pipe = _pipe(eng, ladder[:1], clk)
    xs = _events(cfg, 12)
    warm = _replay(pipe, clk, xs[:4], 0.5)          # compile before breaking
    assert all(r.status == "answered" for r in warm)

    flaky = break_engine_key(eng, ladder[0].key, times=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        r_broken = pipe.push(xs[4], now=clk.advance(1e-3))
        pipe.drain()
    assert flaky.raised == 1
    assert r_broken.status == "failed"
    assert isinstance(r_broken.error, InjectedFault)

    after = _replay(pipe, clk, xs[5:], 0.5)         # recovered, same key
    assert all(r.status == "answered" for r in after)
    acc = pipe.verify_accounting()[ladder[0].key]
    assert acc["failed"] == 1 and acc["answered"] == len(xs) - 1


def test_chaos_corrupt_cache_entry_serves_with_one_warning(gru_tagger,
                                                           ladder, tmp_path):
    """Corrupted persistent compile-cache entries cost one warning + one
    cold compile — the stream is still answered correctly."""
    cfg, params = gru_tagger
    xs = _events(cfg, 8)
    warm = RNNServingEngine(cfg, params, max_batch=8,
                            cache_dir=tmp_path)
    pipe = StreamingPipeline(warm, ladder[:1], deadline_us=50.0,
                             prewarm=True, clock=VirtualClock())
    n = corrupt_cache_entries(tmp_path)
    assert n >= 1

    eng = RNNServingEngine(cfg, params, max_batch=8, cache_dir=tmp_path)
    clk = VirtualClock()
    with pytest.warns(RuntimeWarning, match="quarantined"):
        pipe2 = StreamingPipeline(eng, ladder[:1], deadline_us=50.0,
                                  prewarm=True, clock=clk)
    reqs = _replay(pipe2, clk, xs, 0.5)
    assert all(r.status == "answered" for r in reqs)
    want = eng.predict(xs, schedule=ladder[0].schedule, fp=ladder[0].fp)
    np.testing.assert_array_equal(np.stack([r.result for r in reqs]), want)


def test_chaos_backwards_clock_step_absorbed(engine, ladder):
    """A clock that steps backwards mid-stream is clamped: counted, no
    negative stage durations, accounting intact."""
    cfg = engine.cfg
    clk = VirtualClock()
    pipe = _pipe(engine, ladder[:1], clk)
    rate = pipe._rung_rate(0)
    dt = 1.0 / rate
    xs = _events(cfg, 30)
    reqs = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i, x in enumerate(xs):
            if i == 10:
                clk.step_back(50 * dt)              # NTP-style backwards jump
            t = clk.advance(dt) if i else clk.t
            reqs.append(pipe.push(x, now=t))
            pipe.pump(now=t)
        pipe.drain()
    assert pipe.clock_steps > 0
    pipe.verify_accounting()
    for r in reqs:
        if r.status == "answered":
            times = [r.arrival_s] + [r.stamps[s] for s in STAGES]
            assert all(a <= b + 1e-15 for a, b in zip(times, times[1:]))
    rep = pipe.stage_report()
    for stage in STAGES:
        assert rep[stage]["sim"]["latency_max_s"] >= 0


def test_chaos_full_matrix_drains_without_deadlock(engine, ladder):
    """All fault classes at once: the stream still drains completely and
    every request is accounted for."""
    cfg = engine.cfg
    clk = VirtualClock()
    faults = (FaultInjector()
              .stall("ingest", 1e-6, times=2, after=2)
              .stall("infer", 20e-6, after=10)
              .fail("prep", after=7)
              .fail("sink", after=15))
    pipe = _pipe(engine, ladder, clk, faults=faults)
    rate = pipe._rung_rate(0)
    dt = 1.0 / (1.5 * rate)
    xs = _events(cfg, 80)
    reqs = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i, x in enumerate(xs):
            if i == 40:
                clk.step_back(10 * dt)
            t = clk.advance(dt) if i else clk.t
            reqs.append(pipe.push(x, now=t))
            pipe.pump(now=t)
        pipe.drain()
    assert pipe.in_flight() == 0                    # fully drained
    acc = pipe.verify_accounting()
    total = sum(c["submitted"] for c in acc.values())
    assert total == 80
    assert all(r.status in ("answered", "shed", "failed") for r in reqs)
    assert sum(c["failed"] for c in acc.values()) == 2
    for r in reqs:
        if r.status == "answered":
            assert r.stamps["infer"] <= r.deadline_s + 1e-12


# ---------------------------------------------------------------------------
# FaultInjector / VirtualClock units
# ---------------------------------------------------------------------------


def test_fault_injector_consumption_order():
    fi = FaultInjector().stall("prep", 0.5, times=2).fail("prep", after=1)
    assert fi.stall_s("prep") == 0.5
    assert fi.stall_s("infer") == 0.0               # wrong stage: untouched
    fi.check("prep")                                # after=1: skipped once
    with pytest.raises(InjectedFault):
        fi.check("prep")
    assert fi.stall_s("prep") == 0.5
    assert fi.stall_s("prep") == 0.0                # exhausted
    assert fi.armed() == 0
    assert fi.fired == ["stall:prep", "fail:prep", "stall:prep"]


def test_virtual_clock():
    clk = VirtualClock(1.0)
    assert clk() == 1.0
    assert clk.advance(0.5) == 1.5
    assert clk.step_back(1.0) == 0.5
    with pytest.raises(ValueError):
        clk.advance(-0.1)
    with pytest.raises(ValueError):
        FaultInjector().stall("x", -1.0)
