"""Per-arch smoke tests: reduced config of the same family, one forward +
train step on CPU, asserting output shapes and no NaNs (assignment spec)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig
from repro.models import build_model
from repro.registry import ASSIGNED_ARCHS, get_config
from repro.testing import tiny_config
from repro.training import adamw_init, adamw_update


def _batch(cfg, b=2, s=32):
    if cfg.enc_dec:
        return {"tokens": jnp.zeros((b, 16), jnp.int32),
                "labels": jnp.ones((b, 16), jnp.int32),
                "frame_embeds": jnp.full((b, s, cfg.d_model), 0.01,
                                         jnp.float32)}
    if cfg.frontend == "vision":
        return {"tokens": jnp.zeros((b, s - cfg.n_frontend_tokens), jnp.int32),
                "labels": jnp.ones((b, s), jnp.int32),
                "img_embeds": jnp.full((b, cfg.n_frontend_tokens, cfg.d_model),
                                       0.01, jnp.float32)}
    if cfg.family == "rnn":
        r = cfg.rnn
        return {"x": jnp.zeros((b, r.seq_len, r.input_size), jnp.float32),
                "y": jnp.zeros((b,), jnp.int32)}
    return {"tokens": jnp.zeros((b, s), jnp.int32),
            "labels": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = tiny_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    # forward: logits shape + finite
    logits = m.forward(params, {k: v for k, v in batch.items()
                                if k != "labels"})
    assert logits.shape[0] == 2
    from repro.models.transformer import padded_vocab
    assert logits.shape[-1] == padded_vocab(cfg)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one real optimizer step: loss finite, params move
    opt = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    st = adamw_init(params, opt)
    (loss, metrics), g = jax.value_and_grad(
        lambda p: m.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    new_params, st, _ = adamw_update(params, g, st, opt)
    moved = any(
        float(jnp.max(jnp.abs(new_params[k].astype(jnp.float32)
                              - params[k].astype(jnp.float32)))) > 0
        for k in params)
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    if arch == "mamba2-780m":
        assert cfg.ssm.d_state == 128
    if arch == "qwen2-moe-a2.7b":
        assert (cfg.moe.n_experts, cfg.moe.top_k,
                cfg.moe.n_shared_experts) == (60, 4, 4)
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (128, 8)
    if arch == "recurrentgemma-9b":
        assert cfg.rglru.pattern == ("rglru", "rglru", "local_attn")
