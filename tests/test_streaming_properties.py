"""Property tests for the streaming pipeline's accounting & deadline laws.

Runs under real hypothesis when installed, else under the deterministic
``tests/_hypothesis_stub.py`` fallback (conftest installs it).  Invariants,
over randomized arrival rates, ladder depths, queue bounds and injected
faults:

  * every submitted request ends in EXACTLY ONE of {answered, shed,
    failed} once the stream is drained;
  * per key, ``shed + answered + failed == submitted`` (exact accounting,
    nothing silent);
  * every ANSWERED request's inference result was available within its
    deadline — including under injected stalls (the dispatch-time re-check
    converts would-be misses into late sheds).

The model under test is the ANALYTICAL service model over a virtual clock,
so every example is exactly reproducible.
"""

import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.autotune import DesignTarget, SpaceSpec, degradation_ladder, select
from repro.models import build_model
from repro.registry import get_config
from repro.serving import (FaultInjector, RNNServingEngine, StreamingPipeline,
                           VirtualClock)

SPEC = SpaceSpec(backends=("xla",), block_batches=(8,))
TERMINAL = ("answered", "shed", "failed")


@pytest.fixture(scope="module")
def harness():
    """One shared engine + 3-rung ladder + a pool of payloads; each example
    builds its own pipeline (cheap: the compiled kernels are shared)."""
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = RNNServingEngine(cfg, params, max_batch=8)
    base = select(cfg, DesignTarget(max_dsp=400, objective="latency"), SPEC)
    rungs = degradation_ladder(cfg, base, spec=SPEC, max_rungs=3)
    r = cfg.rnn
    xs = np.random.RandomState(0).randn(
        64, r.seq_len, r.input_size).astype(np.float32)
    return eng, rungs, xs


def _run_stream(harness, *, n, rate_mult, rungs, max_queue, deadline_us,
                faults=None, pump_every=1):
    eng, ladder, xs = harness
    clk = VirtualClock()
    pipe = StreamingPipeline(eng, ladder[:rungs], deadline_us=deadline_us,
                             max_queue=max_queue, clock=clk, prewarm=False,
                             faults=faults)
    dt = 1.0 / (rate_mult * pipe._rung_rate(0))
    reqs = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i in range(n):
            t = clk.advance(dt) if i else clk.t
            reqs.append(pipe.push(xs[i % len(xs)], now=t))
            if i % pump_every == 0:
                pipe.pump(now=t)
        pipe.drain()
    return pipe, reqs


@settings(max_examples=12)
@given(n=st.integers(5, 80), rate_pct=st.integers(25, 400),
       rungs=st.integers(1, 3), max_queue=st.integers(1, 32),
       pump_every=st.integers(1, 5))
def test_every_request_exactly_one_terminal_state(harness, n, rate_pct,
                                                  rungs, max_queue,
                                                  pump_every):
    pipe, reqs = _run_stream(harness, n=n, rate_mult=rate_pct / 100.0,
                             rungs=rungs, max_queue=max_queue,
                             deadline_us=50.0, pump_every=pump_every)
    assert pipe.in_flight() == 0
    assert len(reqs) == n
    for r in reqs:
        assert r.status in TERMINAL, (r.req_id, r.status)
        # the terminal state is exclusive: shed has a reason and no result,
        # failed has an error, answered has a result
        if r.status == "shed":
            assert r.shed_reason is not None and r.result is None
        if r.status == "failed":
            assert r.error is not None
        if r.status == "answered":
            assert r.result is not None and r.error is None


@settings(max_examples=12)
@given(n=st.integers(5, 80), rate_pct=st.integers(25, 400),
       rungs=st.integers(1, 3), max_queue=st.integers(1, 32),
       deadline_us=st.floats(1.0, 100.0))
def test_shed_answered_failed_sums_to_submitted_per_key(harness, n, rate_pct,
                                                        rungs, max_queue,
                                                        deadline_us):
    pipe, reqs = _run_stream(harness, n=n, rate_mult=rate_pct / 100.0,
                             rungs=rungs, max_queue=max_queue,
                             deadline_us=deadline_us)
    acc = pipe.verify_accounting()          # raises on any imbalance
    for key, c in acc.items():
        assert c["shed"] + c["answered"] + c["failed"] == c["submitted"], key
        by_status = {
            "answered": sum(1 for r in reqs
                            if r.key == key and r.status == "answered"),
            "shed": sum(1 for r in reqs
                        if r.key == key and r.status == "shed"),
            "failed": sum(1 for r in reqs
                          if r.key == key and r.status == "failed"),
        }
        # counters agree with the request objects themselves
        assert by_status["answered"] == c["answered"]
        assert by_status["shed"] == c["shed"]
        assert by_status["failed"] == c["failed"]
    assert sum(c["submitted"] for c in acc.values()) == n


@settings(max_examples=12)
@given(n=st.integers(10, 60), rate_pct=st.integers(50, 300),
       stall_us=st.floats(0.0, 200.0), stall_after=st.integers(0, 20),
       deadline_us=st.floats(2.0, 80.0))
def test_answered_requests_meet_deadline_under_stalls(harness, n, rate_pct,
                                                      stall_us, stall_after,
                                                      deadline_us):
    """The deadline law survives injected infer stalls of ANY length: a
    stall may shed requests (late or at enqueue) but never produces an
    answered request whose inference missed its deadline."""
    faults = FaultInjector().stall("infer", stall_us * 1e-6,
                                   after=stall_after)
    pipe, reqs = _run_stream(harness, n=n, rate_mult=rate_pct / 100.0,
                             rungs=2, max_queue=32, deadline_us=deadline_us,
                             faults=faults)
    pipe.verify_accounting()
    for r in reqs:
        if r.status == "answered":
            assert r.stamps["infer"] <= r.deadline_s + 1e-12, \
                (r.req_id, r.stamps, r.deadline_s)
    for c in pipe.counts.values():
        assert c.deadline_miss == 0
