"""Property tests for the router's exactly-once and accounting laws.

Runs under real hypothesis when installed, else under the deterministic
``tests/_hypothesis_stub.py`` fallback (conftest installs it).  Over
randomized pool sizes, fault plans (crash/stall/flap with randomized
arming counters), retry/hedge policies and defer/immediate interleavings:

  * every submitted request ends in EXACTLY ONE terminal state
    (``answered | failed | shed``) once the router is flushed;
  * no duplicate answers: an answered request has exactly ONE surviving
    ``ok`` attempt (hedged/straggler duplicates cancelled and counted);
  * the per-key counters agree EXACTLY with a recount over the request
    objects themselves — ``submitted == answered + failed + shed +
    in_flight`` with ``in_flight == 0`` after flush, and hedges reconcile
    (``hedges == hedge_wins + hedge_cancelled``);
  * every answered result is bit-identical to the single-replica oracle.

All service times are analytic over explicit ``now`` stamps, so every
example is exactly reproducible.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import build_model
from repro.registry import get_config
from repro.serving import (EngineReplica, ReplicaPool, RNNServingEngine,
                           Router, RouterPolicy)
from repro.serving.faults import crash_replica, flapping, slow_replica

CFG = get_config("top-tagging-gru")
TERMINAL = ("answered", "failed", "shed")
N_ENGINES = 3


@pytest.fixture(scope="module")
def harness():
    """Shared compiled engines + oracle outputs; every example wraps the
    engines in FRESH replicas (fresh fault sets / health / ring)."""
    params = build_model(CFG).init(jax.random.PRNGKey(0))
    engines = [RNNServingEngine(CFG, params) for _ in range(N_ENGINES)]
    oracle = RNNServingEngine(CFG, params)
    r = CFG.rnn
    xs = np.random.RandomState(1).randn(
        12, r.seq_len, r.input_size).astype(np.float32)
    want = [oracle.predict_one(x) for x in xs]
    return engines, xs, want


def _build_router(engines, n_replicas, policy):
    pool = ReplicaPool([EngineReplica(f"r{i}", engines[i])
                        for i in range(n_replicas)])
    return pool, Router(pool, policy=policy)


def _arm(pool, rid, fault_kind, after, times):
    rep = pool.get(rid)
    if fault_kind == "crash":
        crash_replica(rep, after=after, times=times)
    elif fault_kind == "stall":
        slow_replica(rep, 0.05, after=after, times=times)  # > any timeout
    elif fault_kind == "flap":
        flapping(rep, period=max(times, 1), after=after)
    # "none": healthy replica


def _check_laws(router, want, sent):
    """The shared postcondition: exactly-once + exact accounting +
    bit-identity, cross-checked against a manual recount."""
    assert all(r.status in TERMINAL for r in router._requests)
    for r in router._requests:
        oks = [a for a in r.attempts if a.outcome == "ok"]
        if r.status == "answered":
            assert len(oks) == 1 and r.winner == oks[0].replica_id
            np.testing.assert_array_equal(r.result, want[sent[r.req_id]])
        else:
            assert not oks and r.result is None
    acc = router.verify_router_accounting()            # raises on any lie
    recount = {}
    for r in router._requests:
        d = recount.setdefault(r.key, dict.fromkeys(TERMINAL, 0))
        d[r.status] += 1
    for key, row in acc.items():
        assert row["in_flight"] == 0
        assert row["submitted"] == sum(recount[key].values())
        for s in TERMINAL:
            assert row[s] == recount[key][s]
        assert row["hedges"] == row["hedge_wins"] + row["hedge_cancelled"]
        assert row["duplicates"] <= row["hedges"] + row["timeouts"]


@settings(max_examples=20)
@given(n_replicas=st.integers(min_value=1, max_value=3),
       n_requests=st.integers(min_value=1, max_value=10),
       fault_kind=st.sampled_from(["none", "crash", "stall", "flap"]),
       fault_rid=st.integers(min_value=0, max_value=2),
       after=st.integers(min_value=0, max_value=3),
       times=st.integers(min_value=1, max_value=4),
       max_retries=st.integers(min_value=0, max_value=3),
       consecutive=st.integers(min_value=1, max_value=3),
       hedge=st.booleans(),
       defer_mask=st.integers(min_value=0, max_value=1023))
def test_exactly_one_terminal_state_under_chaos(
        harness, n_replicas, n_requests, fault_kind, fault_rid, after,
        times, max_retries, consecutive, hedge, defer_mask):
    engines, xs, want = harness
    policy = RouterPolicy(timeout_s=0.01, max_retries=max_retries,
                          consecutive_failures=consecutive,
                          hedge_after_s=(0.0 if hedge else None),
                          probe_interval_s=1e9)
    pool, router = _build_router(engines, n_replicas, policy)
    _arm(pool, f"r{fault_rid % n_replicas}", fault_kind, after, times)
    sent = {}
    for i in range(n_requests):
        rr = router.submit(xs[i % len(xs)], now=i * 1e-3,
                           defer=bool(defer_mask >> i & 1))
        sent[rr.req_id] = i % len(xs)
    router.flush(now=n_requests * 1e-3)
    _check_laws(router, want, sent)


@settings(max_examples=12)
@given(n_replicas=st.integers(min_value=2, max_value=3),
       n_requests=st.integers(min_value=2, max_value=8),
       stall_rid=st.integers(min_value=0, max_value=2),
       stall_times=st.integers(min_value=1, max_value=6),
       hedge_every=st.booleans())
def test_hedging_never_duplicates_answers(harness, n_replicas, n_requests,
                                          stall_rid, stall_times,
                                          hedge_every):
    engines, xs, want = harness
    # hedge threshold below the injected stall: a stalled primary always
    # fires a hedge; hedge_every additionally hedges the FAST path too
    policy = RouterPolicy(timeout_s=0.1,
                          hedge_after_s=(0.0 if hedge_every else 1e-3),
                          probe_interval_s=1e9)
    pool, router = _build_router(engines, n_replicas, policy)
    slow_replica(pool.get(f"r{stall_rid % n_replicas}"), 5e-3,
                 times=stall_times)
    sent = {}
    for i in range(n_requests):
        rr = router.submit(xs[i % len(xs)], now=i * 1e-3)
        sent[rr.req_id] = i % len(xs)
        assert rr.status == "answered"                 # stall < timeout
    _check_laws(router, want, sent)
    total = sum(c.duplicates for c in router.counts.values())
    hedges = sum(c.hedges for c in router.counts.values())
    assert total <= hedges                             # dedup bounded


@settings(max_examples=12)
@given(n_requests=st.integers(min_value=1, max_value=8),
       kill_at=st.integers(min_value=0, max_value=7),
       n_replicas=st.integers(min_value=2, max_value=3))
def test_crash_between_defer_and_flush_loses_nothing(harness, n_requests,
                                                     kill_at, n_replicas):
    """The chaos window the tentpole exists for: requests sitting
    in_flight when their placed replica dies must still reach exactly one
    terminal state at flush, answered by a surviving replica."""
    engines, xs, want = harness
    policy = RouterPolicy(consecutive_failures=1, probe_interval_s=1e9)
    pool, router = _build_router(engines, n_replicas, policy)
    sent = {}
    for i in range(n_requests):
        rr = router.submit(xs[i % len(xs)], now=i * 1e-3, defer=True)
        sent[rr.req_id] = i % len(xs)
    assert router.in_flight() == n_requests
    router.verify_router_accounting()                  # exact while pending
    victim = router.place(router._requests[0].key)
    if kill_at % 2 == 0:                               # kill placed replica
        crash_replica(victim)
    router.flush(now=1.0)
    assert router.in_flight() == 0
    assert all(r.status == "answered" for r in router._requests)
    _check_laws(router, want, sent)
