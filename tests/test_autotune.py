"""Auto-scheduler conformance + explorer properties.

The conformance stake of the autotune layer: the explorer only ever selects
among already-conformant points, so ANY auto-picked schedule must bit-match
the golden model and the engine must serve a target-carrying stream
bit-identically to direct ``predict`` under the selected schedule.

Property tests (hypothesis, or the deterministic stub the conftest
installs): every point the explorer enumerates survives the
``schedule_key``/``from_key`` round-trip, and no frontier point is dominated
by any legal point in the enumerated space.
"""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.autotune import (DesignTarget, InfeasibleTargetError, SpaceSpec,
                            divisors, enumerate_space, explore, is_feasible,
                            pareto, select, violation)
from repro.config import FixedPointConfig
from repro.core.hls import price_point
from repro.core.hls.resources import gate_count
from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.models import build_model
from repro.registry import get_config
from repro.serving import LMServingEngine, RNNServingEngine
from repro.testing import (assert_schedule_conformance,
                           assert_serving_conformance, tiny_config)

CFG = get_config("top-tagging-lstm")
GRU_CFG = get_config("top-tagging-gru")

#: a CPU-friendly slice of the space, shared by most tests
SMALL_SPEC = SpaceSpec(reuse_factors=(1, 2, 4), iis=(0, 1),
                       backends=("pallas_interpret",))
XLA_SPEC = SpaceSpec(reuse_factors=(1, 2, 4), iis=(0, 1),
                     backends=("xla",))

FPS = (None, FixedPointConfig(16, 6))


def _params_for(cfg):
    return build_model(cfg).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def lstm_engine():
    return RNNServingEngine(CFG, _params_for(CFG), max_batch=8)


@pytest.fixture(scope="module")
def gru_engine():
    return RNNServingEngine(GRU_CFG, _params_for(GRU_CFG), max_batch=8)


# ---------------------------------------------------------------------------
# Space enumeration
# ---------------------------------------------------------------------------


def test_space_is_legal_deduped_deterministic():
    space = enumerate_space(CFG, SMALL_SPEC)
    assert space                                   # non-empty
    gd = gate_count(CFG.rnn.cell) * CFG.rnn.hidden
    keys = [s.key() for s in space]
    assert len(keys) == len(set(keys))             # deduplicated
    assert keys == sorted(keys)                    # deterministic order
    for s in space:
        assert gd % s.reuse_factor == 0            # executes exactly as named
        assert s.effective_reuse(gd) == s.reuse_factor
        if s.hoist_reuse > 1:
            assert s.hoist_input
        if s.ii:
            assert s.mode == "pipeline"
    assert enumerate_space(CFG, SMALL_SPEC) == space


def test_space_full_reuse_axis_is_divisors():
    space = enumerate_space(CFG, SpaceSpec(modes=("static",),
                                           hoist=(False,)))
    gd = gate_count(CFG.rnn.cell) * CFG.rnn.hidden
    assert {s.reuse_factor for s in space} == set(divisors(gd))


def test_space_prunes_misaligned_tpu_points():
    """pallas_tpu points whose column tile is off the 128-lane boundary are
    pruned (they would raise at dispatch), never clamped."""
    spec = SpaceSpec(reuse_factors=None, modes=("static",), hoist=(False,),
                     block_batches=(8,), backends=("pallas_tpu",))
    gd = gate_count(CFG.rnn.cell) * CFG.rnn.hidden   # 80: no 128-wide tile
    assert enumerate_space(CFG, spec) == ()
    big = get_config("quickdraw-lstm")               # h=128 -> gd=512
    aligned = enumerate_space(big, spec)
    assert aligned
    g2 = gate_count(big.rnn.cell) * big.rnn.hidden
    for s in aligned:
        assert (g2 // s.reuse_factor) % 128 == 0


# ---------------------------------------------------------------------------
# Property: schedule_key / from_key round-trip over the enumerated space
# ---------------------------------------------------------------------------

_PROP_SPACE = enumerate_space(
    CFG, SpaceSpec(reuse_factors=None, hoist_reuses=(1, 2, 4),
                   iis=(0, 1, 2, 4), block_batches=(1, 8, 128),
                   backends=("auto", "xla", "pallas_interpret")))
_PROP_FPS = (None, FixedPointConfig(16, 6),
             FixedPointConfig(8, 3, rounding="trn", saturation="wrap"),
             FixedPointConfig(24, 12, signed=False))


@settings(max_examples=60)
@given(i=st.integers(0, len(_PROP_SPACE) - 1),
       j=st.integers(0, len(_PROP_FPS) - 1))
def test_schedule_key_roundtrip_over_enumerated_space(i, j):
    """Every token an explorer-enumerated point emits must survive the
    inverse, with and without the fp tail."""
    s, fp = _PROP_SPACE[i], _PROP_FPS[j]
    assert KernelSchedule.from_key(s.key()) == s
    assert KernelSchedule.from_key(schedule_key(s, fp)) == s


def test_schedule_key_roundtrip_exhaustive_small_space():
    """The stub-friendly exhaustive sweep of the same invariant."""
    for s in enumerate_space(CFG, SMALL_SPEC):
        for fp in _PROP_FPS:
            assert KernelSchedule.from_key(schedule_key(s, fp)) == s


# ---------------------------------------------------------------------------
# Pareto frontier
# ---------------------------------------------------------------------------


def test_frontier_nondominated_by_any_legal_point():
    """Acceptance criterion: no returned point is dominated in
    (latency_cycles, dsp, bram) by ANY legal point in the enumerated
    space."""
    ex = explore(CFG, spec=SMALL_SPEC)
    assert ex.frontier
    for f in ex.frontier:
        for p in ex.points:
            assert not p.dominates(f), (p.key, f.key)
    # and every non-frontier point IS dominated by some frontier point
    front_keys = {f.key for f in ex.frontier}
    for p in ex.points:
        if p.key not in front_keys:
            assert any(f.dominates(p) for f in ex.frontier), p.key


def test_frontier_latency_monotone_in_reuse_static():
    """Along the static-mode R axis the frontier's own pricing must be the
    paper's curve: latency strictly rises, DSP strictly falls."""
    pts = [price_point(CFG, KernelSchedule(reuse_factor=r, mode="static",
                                           block_batch=8,
                                           backend="pallas_interpret"))
           for r in (1, 2, 4, 8)]
    lats = [p.latency_cycles for p in pts]
    dsps = [p.dsp for p in pts]
    assert lats == sorted(lats) and len(set(lats)) == len(lats)
    assert dsps == sorted(dsps, reverse=True) and len(set(dsps)) == len(dsps)


def test_pareto_of_frontier_is_frontier():
    ex = explore(CFG, spec=SMALL_SPEC)
    assert pareto(ex.frontier) == ex.frontier


# ---------------------------------------------------------------------------
# Target feasibility + selection
# ---------------------------------------------------------------------------


def test_select_respects_budgets():
    r1 = select(CFG, DesignTarget(objective="latency"), SMALL_SPEC)
    assert r1.schedule.reuse_factor == 1           # unconstrained: fastest
    cap = r1.dsp - 1                               # force R > 1
    saver = select(CFG, DesignTarget(max_dsp=cap), SMALL_SPEC)
    assert saver.dsp <= cap and saver.latency_cycles >= r1.latency_cycles
    thr = select(CFG, DesignTarget(min_throughput_eps=1e7,
                                   objective="throughput"), SMALL_SPEC)
    assert thr.ii_cycles <= 2                      # pipeline/nonstatic pick
    assert thr.schedule.mode in ("pipeline", "nonstatic")


def test_select_feasible_points_all_meet_target():
    target = DesignTarget(max_latency_us=1.0, max_dsp=5000)
    ex = explore(CFG, target, SMALL_SPEC)
    assert ex.feasible
    for p in ex.feasible:
        assert is_feasible(p, target)
        assert p.latency_us(target.clock_mhz) <= 1.0 and p.dsp <= 5000
    assert ex.best is ex.feasible[0]


def test_infeasible_target_names_nearest_point():
    target = DesignTarget(max_latency_us=1e-4)     # nothing is this fast
    with pytest.raises(InfeasibleTargetError) as ei:
        select(CFG, target, SMALL_SPEC)
    err = ei.value
    assert err.nearest is not None
    assert err.nearest.key in str(err)             # nearest point is NAMED
    assert "nearest-to-feasible" in str(err)
    assert violation(err.nearest, target) > 0
    # nearest really is nearest: no legal point violates less
    for p in explore(CFG, target, SMALL_SPEC).points:
        assert violation(p, target) >= violation(err.nearest, target)


def test_replicas_axis_scales_throughput_feasibility():
    """K data-parallel replicas make a K x throughput floor feasible: the
    constraint is read against aggregate events/s, everything else
    (latency, resources) stays per-replica."""
    best_eps = max(p.throughput_eps(200.0)
                   for p in explore(CFG, DesignTarget(), SMALL_SPEC).points)
    floor = best_eps * 2.5
    single = DesignTarget(min_throughput_eps=floor, objective="throughput")
    with pytest.raises(InfeasibleTargetError):
        select(CFG, single, SMALL_SPEC)
    tripled = dataclasses.replace(single, replicas=3)
    pt = select(CFG, tripled, SMALL_SPEC)
    assert pt.throughput_eps(200.0) * 3 >= floor
    assert is_feasible(pt, tripled) and not is_feasible(pt, single)
    assert "over 3 replicas" in tripled.describe()


def test_infeasible_throughput_suggests_smallest_replica_count():
    best_eps = max(p.throughput_eps(200.0)
                   for p in explore(CFG, DesignTarget(), SMALL_SPEC).points)
    target = DesignTarget(min_throughput_eps=best_eps * 2.5,
                          objective="throughput")
    with pytest.raises(InfeasibleTargetError) as ei:
        select(CFG, target, SMALL_SPEC)
    err = ei.value
    assert err.suggested_replicas == 3                 # ceil(2.5)
    assert err.suggested_point is not None
    assert f"replicas={err.suggested_replicas}" in str(err)
    assert err.suggested_point.key in str(err)
    # the suggestion is REAL: a target with that many replicas selects
    fixed = dataclasses.replace(target, replicas=err.suggested_replicas)
    assert select(CFG, fixed, SMALL_SPEC) is not None
    # and it is the SMALLEST such count
    with pytest.raises(InfeasibleTargetError):
        select(CFG, dataclasses.replace(
            target, replicas=err.suggested_replicas - 1), SMALL_SPEC)


def test_no_replica_suggestion_for_latency_or_resource_busts():
    """Replication cannot fix a per-replica latency or resource bust —
    the error must NOT suggest scaling out."""
    with pytest.raises(InfeasibleTargetError) as ei:
        select(CFG, DesignTarget(max_latency_us=1e-4), SMALL_SPEC)
    assert ei.value.suggested_replicas is None
    assert "replicas=" not in str(ei.value)
    # throughput floor AND an impossible latency budget: still no
    # suggestion (no point clears the non-throughput constraints)
    with pytest.raises(InfeasibleTargetError) as ei:
        select(CFG, DesignTarget(max_latency_us=1e-4,
                                 min_throughput_eps=1e12), SMALL_SPEC)
    assert ei.value.suggested_replicas is None


def test_replicas_axis_validation():
    with pytest.raises(ValueError, match="replicas"):
        DesignTarget(replicas=0)
    with pytest.raises(ValueError, match="replicas"):
        DesignTarget(replicas=1.5)
    assert DesignTarget(replicas=2).replicas == 2


def test_select_measured_refinement_returns_topk_member():
    target = DesignTarget(objective="latency")
    ex = explore(CFG, target, XLA_SPEC)
    top_keys = {p.key for p in ex.feasible[:3]}
    pt = select(CFG, target, XLA_SPEC, measure_top_k=3)
    assert pt.key in top_keys


def test_select_measured_refinement_never_degrades_resources_objective():
    """Wall clock carries no resource information: under
    objective="resources" the analytic (DSP-optimal) pick must stand."""
    target = DesignTarget(objective="resources")
    analytic = select(CFG, target, XLA_SPEC)
    assert select(CFG, target, XLA_SPEC, measure_top_k=3).key == analytic.key


def test_select_empty_space_raises_clear_error():
    """An all-pruned space (e.g. pallas_tpu alignment on gate_dim 80) must
    raise an explanatory ValueError, not min()-on-empty."""
    spec = SpaceSpec(modes=("static",), hoist=(False,),
                     backends=("pallas_tpu",))
    assert enumerate_space(CFG, spec) == ()
    with pytest.raises(ValueError, match="space is empty"):
        select(CFG, DesignTarget(), spec)


# ---------------------------------------------------------------------------
# Conformance stake: the explorer only selects among conformant points
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cell", ("lstm", "gru"))
def test_explored_frontier_points_are_conformant(cell):
    cfg = CFG if cell == "lstm" else GRU_CFG
    ex = explore(cfg, spec=SpaceSpec(reuse_factors=(1, 4),
                                     backends=("pallas_interpret",)))
    for p in ex.frontier:
        err = assert_schedule_conformance(cell, p.schedule, B=3,
                                          T=cfg.rnn.seq_len,
                                          F=cfg.rnn.input_size,
                                          H=cfg.rnn.hidden)
        assert np.isfinite(err)


# ---------------------------------------------------------------------------
# Engine auto-scheduling (the serving side of the tentpole)
# ---------------------------------------------------------------------------

#: targets that force distinct (mode x R) picks — the conformance cells
TARGETS = (
    DesignTarget(objective="latency"),                       # static R=1
    DesignTarget(max_dsp=600),                               # static, R up
    DesignTarget(min_throughput_eps=1e7, objective="throughput"),  # pipeline
)


@pytest.mark.parametrize("cell", ("lstm", "gru"))
@pytest.mark.parametrize("fp", FPS, ids=("float", "ap16_6"))
@pytest.mark.parametrize("ti", range(len(TARGETS)))
def test_auto_schedule_bitmatches_direct_predict(cell, fp, ti, rng,
                                                 lstm_engine, gru_engine):
    """Acceptance criterion: auto_schedule(target) serves bit-identically to
    predict() under the selected schedule, per (cell x mode x R x fp)."""
    cfg = CFG if cell == "lstm" else GRU_CFG
    base = lstm_engine if cell == "lstm" else gru_engine
    target = TARGETS[ti]
    if fp is not None:
        import dataclasses
        target = dataclasses.replace(target, fp=fp)
    eng = RNNServingEngine(cfg, base.params, max_batch=8)
    pt = eng.auto_schedule(target, spec=SMALL_SPEC, warmup=False)
    x = rng.randn(5, cfg.rnn.seq_len, cfg.rnn.input_size).astype(np.float32)
    auto = eng.predict(x)                          # engine-default schedule
    direct = eng.predict(x, schedule=pt.schedule, fp=pt.fp)
    np.testing.assert_array_equal(auto, direct)
    # the auto-picked schedule is itself golden-model conformant
    assert_serving_conformance(eng, x, schedule=pt.schedule, fp=pt.fp)
    # and the picked point meets its own target
    assert is_feasible(pt, target)


def test_target_carrying_stream_cobatches_on_selected_key(gru_engine, rng):
    """submit(target=...) resolves the explorer ONCE, lands every request on
    the selected schedule's queue, and bit-matches direct predict."""
    cfg = GRU_CFG
    eng = RNNServingEngine(cfg, gru_engine.params, max_batch=4)
    target = DesignTarget(max_dsp=600)
    x = rng.randn(6, 20, 6).astype(np.float32)
    reqs = [eng.submit(x[i], target=target) for i in range(6)]
    eng.flush(force=True)
    pt = eng.schedule_for_target(target)
    assert len({r.key for r in reqs}) == 1         # one auto-picked queue
    assert reqs[0].key == pt.key
    assert eng.trace_count(pt.key) == 1            # whole stream: one trace
    direct = eng.predict(x, schedule=pt.schedule, fp=pt.fp)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.result), direct[i])


def test_schedule_for_target_memoizes_per_spec(gru_engine):
    """The same target under a DIFFERENT space spec must re-resolve, never
    be served from the other spec's cache."""
    eng = RNNServingEngine(GRU_CFG, gru_engine.params, max_batch=4)
    target = DesignTarget(objective="latency")
    default_pt = eng.schedule_for_target(target)         # engine xla spec
    assert default_pt.schedule.backend == "xla"
    interp_pt = eng.schedule_for_target(target, spec=SMALL_SPEC)
    assert interp_pt.schedule.backend == "pallas_interpret"
    # both resolutions stay cached independently
    assert eng.schedule_for_target(target) is default_pt
    assert eng.schedule_for_target(target, spec=SMALL_SPEC) is interp_pt


def test_engine_infeasible_target_raises_with_nearest(gru_engine):
    eng = RNNServingEngine(GRU_CFG, gru_engine.params, max_batch=4)
    with pytest.raises(InfeasibleTargetError, match="nearest-to-feasible"):
        eng.auto_schedule(DesignTarget(max_latency_us=1e-4), spec=SMALL_SPEC)


def test_default_queue_reports_resolved_schedule(gru_engine, rng):
    """Satellite fix: requests on the bare DEFAULT_SCHEDULE_KEY queue are
    served under — and reported as — the engine's resolved schedule, not an
    estimate-less row."""
    eng = RNNServingEngine(GRU_CFG, gru_engine.params, max_batch=4)
    x = rng.randn(3, 20, 6).astype(np.float32)
    for i in range(3):
        eng.batcher.submit(x[i])                   # no schedule, no key
    done = eng.flush(force=True)
    assert len(done) == 3 and all(r.result is not None for r in done)
    direct = eng.predict(x)                        # the resolved schedule
    for i, r in enumerate(done):
        np.testing.assert_array_equal(np.asarray(r.result), direct[i])
    row = eng.serve_report()["default"]
    assert row["schedule"] == eng.resolved_schedule
    assert row["analytical"] is not None           # priced, not estimate-less
    assert row["resolved_key"] == schedule_key(*eng.resolve())
    assert row["measured"]["served"] == 3


# ---------------------------------------------------------------------------
# LM engine on the schedule-key abstraction
# ---------------------------------------------------------------------------


def test_lm_engine_keyed_decoders_isolate_and_report():
    cfg = tiny_config(get_config("stablelm-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
    a = eng.add_request([3, 4, 5], max_new=2)
    b = eng.add_request([6], max_new=3)
    assert eng.add_request([7]) is None            # default pool full
    sched = KernelSchedule(reuse_factor=2, mode="nonstatic")
    c = eng.add_request([7, 8], max_new=2, schedule=sched)
    assert c is not None                           # own pool, own cache
    done = eng.run_to_completion()
    assert set(done) == {a, b, c}
    report = eng.serve_report()
    assert set(report) == {"default", schedule_key(sched)}
    assert report["default"]["measured"]["served"] == 2
    assert report[schedule_key(sched)]["measured"]["served"] == 1
    assert report[schedule_key(sched)]["schedule"] == sched
    # exactly one decode trace per schedule key (keyed jit-cache criterion)
    assert eng.trace_count("default") == 1
    assert eng.trace_count(schedule_key(sched)) == 1
    # greedy decode identical to a fresh single-key engine (keying the
    # batcher must not change the math)
    ref = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
    ra = ref.add_request([3, 4, 5], max_new=2)
    assert ref.run_to_completion()[ra] == done[a]
