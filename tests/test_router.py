"""Chaos suite for the replicated serving layer (replica pool + router).

Every scenario runs the REAL engines (bit-identity against a single-replica
oracle is part of the contract) while timeouts/hedges/health live in the
simulated clock domain — injected crashes/stalls/flaps are deterministic,
so each test is an exactly reproducible chaos replay:

  * crash mid-traffic -> retry on a different replica -> mark-unhealthy ->
    keys re-place via the hash ring -> probe streak re-admits;
  * straggler -> per-request timeout -> answer discarded, retried;
  * hedging -> first answer wins, loser cancelled, duplicate counted;
  * every request in EXACTLY one terminal state and
    ``submitted == answered + failed + shed + in_flight`` exactly.
"""

import jax
import numpy as np
import pytest

from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.models import build_model
from repro.registry import get_config
from repro.serving import (EngineClosedError, EngineReplica, LMServingEngine,
                           ReplicaPool, RNNServingEngine, Router,
                           RouterPolicy, VirtualClock, format_router_report)
from repro.serving.faults import (ReplicaCrashed, crash_replica, flapping,
                                  slow_replica)
from repro.serving.router import HashRing, ReplicaTimeout

CFG = get_config("top-tagging-gru")


@pytest.fixture(scope="module")
def harness():
    """Shared params + engines; each test wraps them in FRESH replicas
    (fresh fault sets, fresh health state) so compiled traces are reused
    but no chaos leaks between tests."""
    params = build_model(CFG).init(jax.random.PRNGKey(0))
    engines = [RNNServingEngine(CFG, params) for _ in range(4)]
    oracle = RNNServingEngine(CFG, params)
    r = CFG.rnn
    xs = np.random.RandomState(0).randn(
        24, r.seq_len, r.input_size).astype(np.float32)
    return params, engines, oracle, xs


def make_router(harness, n=3, **policy_kw):
    params, engines, _, _ = harness
    pool = ReplicaPool.build(CFG, params, n,
                             make_engine=lambda i: engines[i])
    return pool, Router(pool, policy=RouterPolicy(**policy_kw))


def primary_of(router, schedule=None, fp=None):
    sched, fpr = router.reference_engine.resolve(schedule, fp)
    return router.place(schedule_key(sched, fpr))


# ---------------------------------------------------------------------------
# hash ring
# ---------------------------------------------------------------------------


def test_hash_ring_is_stable_and_orders_every_node():
    a = HashRing(["r0", "r1", "r2"], vnodes=16)
    b = HashRing(["r0", "r1", "r2"], vnodes=16)
    for key in ("k0", "k1", "static-R1-bb128-xla"):
        assert a.ordered(key) == b.ordered(key)        # process-stable
        assert sorted(a.ordered(key)) == ["r0", "r1", "r2"]


def test_hash_ring_removal_moves_only_the_dead_nodes_keys():
    ring = HashRing([f"r{i}" for i in range(4)], vnodes=32)
    keys = [f"sched-{i}" for i in range(64)]
    before = {k: ring.ordered(k)[0] for k in keys}
    # "remove" r1 the way the router does: skip it while walking
    after = {k: next(r for r in ring.ordered(k) if r != "r1") for k in keys}
    for k in keys:
        if before[k] != "r1":
            assert after[k] == before[k]               # untouched
        else:
            assert after[k] != "r1"                    # re-placed


def test_hash_ring_validation():
    with pytest.raises(ValueError, match="at least one"):
        HashRing([])
    with pytest.raises(ValueError, match="vnodes"):
        HashRing(["r0"], vnodes=0)


# ---------------------------------------------------------------------------
# healthy path: bit identity + locality
# ---------------------------------------------------------------------------


def test_router_output_bit_identical_to_single_replica(harness):
    _, _, oracle, xs = harness
    pool, router = make_router(harness, n=3)
    s2 = KernelSchedule(reuse_factor=2, mode="static", backend="xla")
    for i, x in enumerate(xs[:6]):
        rr = router.submit(x, now=i * 1e-4)
        assert rr.status == "answered"
        np.testing.assert_array_equal(rr.result, oracle.predict_one(x))
        rr2 = router.submit(x, schedule=s2, now=i * 1e-4 + 5e-5)
        assert rr2.status == "answered"
        np.testing.assert_array_equal(rr2.result,
                                      oracle.predict_one(x, schedule=s2))
    router.verify_router_accounting()


def test_same_key_lands_on_same_replica(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=3)
    done = [router.submit(x, now=i * 1e-4) for i, x in enumerate(xs[:8])]
    assert len({r.winner for r in done}) == 1          # placement locality


# ---------------------------------------------------------------------------
# the ladder: crash -> retry -> retire -> re-place -> probe -> re-admit
# ---------------------------------------------------------------------------


def test_crash_failover_answers_everything_and_re_places(harness):
    _, _, oracle, xs = harness
    pool, router = make_router(harness, n=3, consecutive_failures=2)
    first = router.submit(xs[0], now=0.0)
    assert first.status == "answered"
    dead = pool.get(first.winner)
    crash_replica(dead)                                # dead board, forever
    done = [router.submit(x, now=0.01 + i * 1e-4)
            for i, x in enumerate(xs[:10])]
    assert all(r.status == "answered" for r in done)
    for r, x in zip(done, xs[:10]):
        np.testing.assert_array_equal(r.result, oracle.predict_one(x))
    assert all(r.winner != dead.replica_id for r in done[2:])
    c = router.counts[first.key]
    assert c.retries >= 1 and c.re_placements >= 1
    assert f"retire:{dead.replica_id}" in router.events
    assert not router._health[dead.replica_id].healthy
    router.verify_router_accounting()


def test_retry_prefers_a_different_replica(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=3, timeout_s=0.01)
    rr0 = router.submit(xs[0], now=0.0)
    crash_replica(pool.get(rr0.winner), times=1)       # one transient crash
    rr = router.submit(xs[1], now=1e-3)
    assert rr.status == "answered"
    assert [a.kind for a in rr.attempts] == ["primary", "retry"]
    assert rr.attempts[0].replica_id != rr.attempts[1].replica_id
    assert rr.attempts[0].outcome == "error"
    assert isinstance(rr.attempts[0].error, ReplicaCrashed)


def test_straggler_times_out_answer_discarded_then_retried(harness):
    _, _, oracle, xs = harness
    pool, router = make_router(harness, n=3, timeout_s=0.01)
    rr0 = router.submit(xs[0], now=0.0)
    slow_replica(pool.get(rr0.winner), 0.05, times=1)  # stall > timeout
    rr = router.submit(xs[1], now=1e-3)
    assert rr.status == "answered"
    np.testing.assert_array_equal(rr.result, oracle.predict_one(xs[1]))
    t0 = rr.attempts[0]
    assert t0.outcome == "timeout" and t0.result is None
    assert isinstance(t0.error, ReplicaTimeout)
    assert rr.attempts[1].replica_id != t0.replica_id
    assert router.counts[rr.key].timeouts == 1
    # exactly ONE surfaced answer even though the straggler finished too
    assert sum(1 for a in rr.attempts if a.outcome == "ok") == 1
    router.verify_router_accounting()


def test_all_replicas_down_fails_then_sheds(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=2, consecutive_failures=1,
                               max_retries=1, probe_interval_s=1e9)
    for rep in pool:
        crash_replica(rep)
    early = router.submit(xs[0], now=0.0)              # attempts ran, failed
    assert early.status == "failed"
    assert isinstance(early.error, ReplicaCrashed)
    late = router.submit(xs[1], now=1e-3)              # nothing left to try
    assert late.status == "shed"
    assert late.shed_reason == "no_healthy_replica"
    assert late.attempts == []
    assert router.healthy_count() == 0
    router.verify_router_accounting()


def test_probe_streak_re_admits_and_keys_flow_back(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=3, consecutive_failures=1,
                               probe_successes=2)
    rr0 = router.submit(xs[0], now=0.0)
    dead = pool.get(rr0.winner)
    crash_replica(dead, times=3)
    router.submit(xs[1], now=1e-3)                     # crash -> retire
    assert not router._health[dead.replica_id].healthy
    assert router.probe(now=0.1) == {dead.replica_id: False}  # still down
    dead.faults.clear()                                # board replaced
    router.probe(now=0.2)
    assert not router._health[dead.replica_id].healthy  # 1 OK < streak of 2
    router.probe(now=0.3)
    assert router._health[dead.replica_id].healthy      # re-admitted
    assert f"readmit:{dead.replica_id}" in router.events
    rr = router.submit(xs[2], now=0.4)
    assert rr.winner == dead.replica_id                 # keys flowed back
    assert router._health[dead.replica_id].readmitted == 1
    router.verify_router_accounting()


def test_flapping_replica_is_survived_and_audited(harness):
    _, _, oracle, xs = harness
    pool, router = make_router(harness, n=3, consecutive_failures=2,
                               probe_interval_s=1e9)
    rr0 = router.submit(xs[0], now=0.0)
    flapper = pool.get(rr0.winner)
    flapping(flapper, period=2)                        # 2 up, 2 down, ...
    done = [router.submit(x, now=1e-3 + i * 1e-4)
            for i, x in enumerate(xs[:16])]
    assert all(r.status == "answered" for r in done)
    for r, x in zip(done, xs[:16]):
        np.testing.assert_array_equal(r.result, oracle.predict_one(x))
    assert any(f.startswith("flap:") for f in flapper.faults.fired)
    router.verify_router_accounting()


# ---------------------------------------------------------------------------
# hedging
# ---------------------------------------------------------------------------


def test_hedge_fires_on_slow_primary_and_first_answer_wins(harness):
    _, _, oracle, xs = harness
    pool, router = make_router(harness, n=3, timeout_s=0.1,
                               hedge_after_s=1e-3)
    rr0 = router.submit(xs[0], now=0.0)                # locate the primary
    slow_replica(pool.get(rr0.winner), 5e-3)           # slow, not timed out
    rr = router.submit(xs[1], now=1e-2)
    assert rr.status == "answered" and rr.hedged
    kinds = [a.kind for a in rr.attempts]
    assert kinds == ["primary", "hedge"]
    assert rr.winner == rr.attempts[1].replica_id      # hedge won
    assert rr.attempts[0].outcome == "cancelled"       # loser cancelled
    assert rr.attempts[0].result is None               # duplicate discarded
    np.testing.assert_array_equal(rr.result, oracle.predict_one(xs[1]))
    c = router.counts[rr.key]
    assert c.hedges == 1 and c.hedge_wins == 1 and c.duplicates == 1
    assert c.hedges == c.hedge_wins + c.hedge_cancelled
    router.verify_router_accounting()


def test_hedge_loser_is_cancelled_when_primary_wins(harness):
    _, _, _, xs = harness
    # hedge_after_s=0 fires a hedge on EVERY request; with equal service
    # the hedge starts later, so the primary always wins
    pool, router = make_router(harness, n=2, timeout_s=0.1,
                               hedge_after_s=0.0)
    rr = router.submit(xs[0], now=0.0)
    assert rr.status == "answered" and rr.hedged
    assert rr.winner == rr.attempts[0].replica_id
    assert rr.attempts[1].outcome == "cancelled"
    c = router.counts[rr.key]
    assert c.hedges == 1 and c.hedge_wins == 0 and c.hedge_cancelled == 1
    assert c.duplicates == 1
    assert sum(1 for a in rr.attempts if a.outcome == "ok") == 1
    router.verify_router_accounting()


def test_no_hedge_on_single_healthy_replica(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=1, hedge_after_s=0.0)
    rr = router.submit(xs[0], now=0.0)
    assert rr.status == "answered" and not rr.hedged
    assert router.counts[rr.key].hedges == 0
    router.verify_router_accounting()


# ---------------------------------------------------------------------------
# accounting: the exact-sum invariant and its tamper alarms
# ---------------------------------------------------------------------------


def test_deferred_submits_count_in_flight_until_flush(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=3)
    rs = [router.submit(x, now=i * 1e-4, defer=True)
          for i, x in enumerate(xs[:5])]
    assert all(r.status == "pending" for r in rs)
    acc = router.verify_router_accounting()            # exact WITH in_flight
    (key,) = acc.keys()
    assert acc[key]["in_flight"] == 5 and acc[key]["answered"] == 0
    done = router.flush(now=1.0)
    assert [r.req_id for r in done] == [r.req_id for r in rs]   # FIFO
    assert all(r.status == "answered" for r in rs)
    acc = router.verify_router_accounting()
    assert acc[key]["in_flight"] == 0 and acc[key]["answered"] == 5


def test_accounting_tamper_raises(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=2)
    rr = router.submit(xs[0], now=0.0)
    router.verify_router_accounting()
    router.counts[rr.key].answered += 1                # lie
    with pytest.raises(AssertionError, match="accounting|disagreement"):
        router.verify_router_accounting()
    router.counts[rr.key].answered -= 1
    rr.attempts[0].outcome = "cancelled"               # lost answer
    with pytest.raises(AssertionError, match="surfaced"):
        router.verify_router_accounting()


def test_router_report_aggregates_replicas_and_keys(harness):
    _, _, _, xs = harness
    pool, router = make_router(harness, n=3, consecutive_failures=1)
    rr0 = router.submit(xs[0], now=0.0)
    crash_replica(pool.get(rr0.winner), times=2)
    for i, x in enumerate(xs[:6]):
        router.submit(x, now=1e-3 + i * 1e-4)
    rep = router.router_report()
    assert set(rep["replicas"]) == {"r0", "r1", "r2"}
    assert rep["pool"]["n"] == 3 and rep["pool"]["healthy"] == 2
    row = rep["keys"][rr0.key]
    assert row["submitted"] == 7 and row["placement"] is not None
    assert any(e.startswith("retire:") for e in rep["pool"]["events"])
    for rid, rrow in rep["replicas"].items():
        assert {"calls", "errors", "healthy", "error_rate",
                "engine_served"} <= set(rrow)
    text = format_router_report(router)
    assert "healthy" in text and rr0.key in text


# ---------------------------------------------------------------------------
# lifecycle: drain/close on engines, replicas, router
# ---------------------------------------------------------------------------


def test_router_close_is_terminal_and_idempotent(harness):
    params, _, _, xs = harness
    # fresh engines: close() retires them for good, so the shared module
    # engines must not be used here
    pool = ReplicaPool.build(CFG, params, 2)
    router = Router(pool)
    router.submit(xs[0], now=0.0, defer=True)
    done = router.close(now=1.0)
    assert len(done) == 1 and done[0].status == "answered"
    assert router.closed and all(rep.closed for rep in pool)
    assert router.close() == []                        # idempotent
    with pytest.raises(EngineClosedError, match="closed"):
        router.submit(xs[1], now=2.0)
    router.verify_router_accounting()                  # still exact


def test_engine_drain_close_refuses_new_work(harness):
    params, _, _, xs = harness
    eng = RNNServingEngine(CFG, params)
    eng.submit(xs[0], now=0.0)
    flushed = eng.close(now=1.0)
    assert len(flushed) == 1 and flushed[0].error is None
    assert eng.closed
    assert eng.close() == []                           # idempotent
    for call in (lambda: eng.submit(xs[0], now=2.0),
                 lambda: eng.predict(xs[:1]),
                 lambda: eng.predict_one(xs[0])):
        with pytest.raises(EngineClosedError, match="drained and retired"):
            call()


def test_lm_engine_drain_close_refuses_new_work():
    from repro.testing import tiny_config
    lm_cfg = tiny_config(get_config("stablelm-3b"))
    m = build_model(lm_cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = LMServingEngine(lm_cfg, params, max_batch=2)
    eng.add_request([3, 5, 7], max_new=3)
    finished = eng.close()
    assert eng.closed and 0 in finished                # drained to terminal
    assert eng.close() == {}                           # idempotent
    with pytest.raises(EngineClosedError):
        eng.add_request([2, 4])


def test_replica_fault_arming_validates_surface(harness):
    params, _, _, _ = harness
    eng = RNNServingEngine(CFG, params)
    with pytest.raises(TypeError, match="ReplicaFaultSet"):
        crash_replica(eng)                             # bare engine: no
    rep = EngineReplica("rX", eng)
    with pytest.raises(ValueError, match=">= 0"):
        slow_replica(rep, -1.0)
    with pytest.raises(ValueError, match=">= 1"):
        flapping(rep, period=0)
    arm = crash_replica(rep, after=1, times=1)
    assert rep.heartbeat() == 0.0                      # 'after' skips one
    with pytest.raises(ReplicaCrashed):
        rep.heartbeat()
    assert rep.heartbeat() == 0.0                      # budget exhausted
    assert not arm.live and rep.faults.armed() == 0
    assert rep.faults.fired == ["crash:rX"]


def test_replica_pool_validation(harness):
    params, engines, _, _ = harness
    with pytest.raises(ValueError, match="at least one"):
        ReplicaPool([])
    with pytest.raises(ValueError, match="duplicate"):
        ReplicaPool([EngineReplica("a", engines[0]),
                     EngineReplica("a", engines[1])])
    with pytest.raises(ValueError, match=">= 1"):
        ReplicaPool.build(CFG, params, 0)


def test_router_policy_validation():
    for bad in (dict(timeout_s=0.0), dict(max_retries=-1),
                dict(jitter=1.0), dict(consecutive_failures=0),
                dict(probe_successes=0), dict(max_error_rate=0.0)):
        with pytest.raises(ValueError):
            RouterPolicy(**bad)


# ---------------------------------------------------------------------------
# streaming integration: capacity-aware admission + mid-stream crash
# ---------------------------------------------------------------------------


def test_streaming_over_router_rerates_on_crash(harness):
    from repro.serving import StreamingPipeline
    params, engines, _, xs = harness
    pool = ReplicaPool.build(CFG, params, 3,
                             make_engine=lambda i: engines[i])
    router = Router(pool, policy=RouterPolicy(consecutive_failures=2))
    clk = VirtualClock()
    pipe = StreamingPipeline(router=router, deadline_us=500.0, clock=clk,
                             prewarm=False)
    assert pipe.capacity() == 3
    base_rate = pipe._rung_rate(0)
    assert pipe.admission_rate() == pytest.approx(3 * base_rate)
    key = pipe.current_point.key
    for i in range(16):
        clk.advance(1e-4)
        pipe.push(xs[i % len(xs)])
        pipe.pump()
        if i == 7:
            crash_replica(pool.get(router._placements[key]))
    pipe.drain()
    assert pipe.capacity() == 2 and pipe.rerates == 1
    assert pipe.admission_rate() == pytest.approx(2 * base_rate)
    counts = pipe.verify_accounting()[key]
    assert counts["answered"] == 16                    # nothing lost
    router.verify_router_accounting()


def test_streaming_rejects_engine_and_router_together(harness):
    from repro.serving import StreamingPipeline
    params, engines, _, _ = harness
    pool = ReplicaPool.build(CFG, params, 2,
                             make_engine=lambda i: engines[i])
    router = Router(pool)
    with pytest.raises(ValueError, match="not both"):
        StreamingPipeline(engines[0], router=router, deadline_us=100.0)
    with pytest.raises(ValueError, match="engine or a router"):
        StreamingPipeline(deadline_us=100.0)
