"""Fault tolerance: heartbeats, stragglers, elastic restart planning,
HLO collective analyzer."""

import numpy as np

from repro.ft import HeartbeatMonitor, StragglerPolicy, plan_elastic_restart
from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes


def test_heartbeat_detects_dead_worker():
    hb = HeartbeatMonitor(n_workers=4, timeout_s=10)
    for w in range(4):
        hb.beat(w, now=0.0)
    assert hb.healthy(now=5.0)
    for w in (0, 1, 3):
        hb.beat(w, now=20.0)
    assert hb.dead_workers(now=25.0) == {2}


def test_straggler_policy_escalates():
    sp = StragglerPolicy(threshold=1.5, patience=2)
    for step in range(3):
        for w in range(4):
            sp.record_step(w, 1.0 if w != 2 else 3.0)
        actions = sp.evaluate()
    assert actions[2] == "evict"
    assert actions[0] == "ok"


def test_straggler_recovers_after_good_steps():
    sp = StragglerPolicy(threshold=1.5, patience=3)
    for w in range(3):
        sp.record_step(w, 1.0)
    sp.record_step(3, 5.0)
    sp.evaluate()
    for w in range(4):
        sp.record_step(w, 1.0)
    actions = sp.evaluate()
    assert actions[3] == "ok"


def test_elastic_plan_prefers_model_axis_intact():
    p = plan_elastic_restart(healthy_chips=511, original_chips=512)
    assert p.mesh_shape == (16, 16)          # drop a pod, keep TP=16
    p = plan_elastic_restart(healthy_chips=200, original_chips=256)
    assert p.mesh_shape[-1] == 16            # TP width preserved
    assert p.mesh_shape[0] * p.mesh_shape[1] <= 200
    p = plan_elastic_restart(healthy_chips=1)
    assert p.mesh_shape == (1, 1)


def test_elastic_batch_rescale():
    p = plan_elastic_restart(healthy_chips=128, original_chips=256)
    assert p.global_batch_scale == 0.5       # keep per-chip batch constant


# -- HLO analyzer -------------------------------------------------------------

SAMPLE = """
%body (param: (s32[], f32[32,16])) -> (s32[], f32[32,16]) {
  %param = (s32[], f32[32,16]{1,0}) parameter(0)
  %gte = f32[32,16]{1,0} get-tuple-element(%param), index=1
  %ag = f32[64,16]{1,0} all-gather(%gte), channel_id=1, replica_groups=[4,2]<=[8], dimensions={0}
  %ar = f32[32,16]{1,0} all-reduce(%gte), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[32,16]{1,0}) tuple(%param, %ar)
}
ENTRY %main (p: f32[32,16]) -> f32[32,16] {
  %p = f32[32,16]{1,0} parameter(0)
  %w = (s32[], f32[32,16]{1,0}) while(%p), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %cp = f32[32,16]{1,0} collective-permute(%p), channel_id=3, source_target_pairs={{0,1}}
  ROOT %out = f32[32,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[32,16]") == 2048
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(s32[], f32[4,4])") == 68
    assert _shape_bytes("pred[]") == 1


def test_analyzer_trip_count_scaling():
    a = analyze_hlo(SAMPLE)
    kinds = {c.kind: c for c in a.collectives}
    assert kinds["all-gather"].count == 7           # inside 7-trip while
    assert kinds["all-reduce"].count == 7
    assert kinds["collective-permute"].count == 1   # entry-level
    # ring models: AG wire = result*(n-1)/n = 4096*1/2
    assert kinds["all-gather"].wire_bytes == 2048
    assert kinds["all-reduce"].wire_bytes == 2 * 2048 * 3 / 4
