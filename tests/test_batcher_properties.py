"""Property tests for the multi-queue schedule-keyed MicroBatcher.

Runs under real hypothesis when installed, else under the deterministic
``tests/_hypothesis_stub.py`` fallback (conftest installs it).  Invariants:
no request is dropped or duplicated, FIFO order holds within a schedule key,
``ready()`` is monotone in time, and a drain never exceeds the key's
``max_batch``.
"""

import random

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.serving import MicroBatcher

KEYS = ("static-R1", "static-R4", "nonstatic-R2")


def _random_stream(n, seed, max_batch, max_wait_s=0.05):
    """A reproducible mixed-key submission stream."""
    rnd = random.Random(seed)
    mb = MicroBatcher(max_batch=max_batch, max_wait_s=max_wait_s)
    t = 0.0
    submitted = []
    for _ in range(n):
        t += rnd.random() * 0.01
        submitted.append(
            mb.submit(np.zeros(2, np.float32), now=t,
                      key=KEYS[rnd.randrange(len(KEYS))]))
    return mb, submitted, t


@settings(max_examples=20)
@given(n=st.integers(1, 60), max_batch=st.integers(1, 9),
       seed=st.integers(0, 10_000))
def test_no_request_dropped_or_duplicated(n, max_batch, seed):
    mb, submitted, t = _random_stream(n, seed, max_batch)
    drained = []
    while mb.pending():
        batch = mb.run(lambda x: x, now=t + 1.0, force=True)
        assert batch, "pending queue must always be drainable with force"
        drained.extend(batch)
    assert sorted(r.req_id for r in drained) == \
        sorted(r.req_id for r in submitted)
    assert all(r.result is not None and r.done_s is not None for r in drained)


@settings(max_examples=20)
@given(n=st.integers(2, 60), max_batch=st.integers(1, 9),
       seed=st.integers(0, 10_000))
def test_fifo_order_within_schedule_key(n, max_batch, seed):
    mb, submitted, t = _random_stream(n, seed, max_batch)
    drained_by_key = {k: [] for k in KEYS}
    while mb.pending():
        for r in mb.run(lambda x: x, now=t + 1.0, force=True):
            drained_by_key[r.key].append(r.req_id)
    for k in KEYS:
        expect = [r.req_id for r in submitted if r.key == k]
        assert drained_by_key[k] == expect, k


@settings(max_examples=20)
@given(n=st.integers(1, 20), max_batch=st.integers(2, 30),
       wait=st.floats(0.001, 0.5), seed=st.integers(0, 10_000))
def test_ready_monotone_in_time(n, max_batch, wait, seed):
    rnd = random.Random(seed)
    mb = MicroBatcher(max_batch=max_batch, max_wait_s=wait)
    t = 0.0
    for _ in range(n):
        t += rnd.random() * 0.01
        mb.submit(np.zeros(1), now=t, key=KEYS[rnd.randrange(len(KEYS))])
    states = [mb.ready(now=t + dt) for dt in np.linspace(0.0, 2 * wait, 12)]
    assert all(b or not a for a, b in zip(states, states[1:])), \
        f"ready() went True -> False without a drain: {states}"
    assert states[-1], "past max_wait_s every non-empty queue must be ready"


@settings(max_examples=20)
@given(n=st.integers(1, 60), max_batch=st.integers(1, 9),
       fast_batch=st.integers(1, 3), seed=st.integers(0, 10_000))
def test_drain_never_exceeds_per_key_max_batch(n, max_batch, fast_batch, seed):
    mb, submitted, t = _random_stream(n, seed, max_batch)
    mb.set_policy(KEYS[0], max_batch=fast_batch)
    while mb.pending():
        batch = mb.run(lambda x: x, now=t + 1.0, force=True)
        keys = {r.key for r in batch}
        assert len(keys) == 1, "one flush never mixes schedule keys"
        limit = fast_batch if keys.pop() == KEYS[0] else max_batch
        assert len(batch) <= limit
