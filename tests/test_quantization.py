"""ap_fixed emulation properties + PTQ machinery (paper Sec. 5.1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import FixedPointConfig
from repro.core.quant.fixed_point import (fixed_point_error_bound, quantize,
                                          quantize_np, saturates)
from repro.core.quant.ptq import binary_auc, multiclass_mean_auc


@given(total=st.integers(4, 22), integer=st.integers(1, 10))
@settings(max_examples=20, deadline=None)
def test_quantize_idempotent(total, integer):
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    x = jnp.asarray(np.random.RandomState(total).randn(64).astype(np.float32))
    q1 = quantize(x, fp)
    q2 = quantize(q1, fp)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@given(total=st.integers(4, 20), integer=st.integers(1, 10))
@settings(max_examples=20, deadline=None)
def test_quantize_on_grid_and_bounded_error(total, integer):
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    r = np.random.RandomState(integer * 7 + total)
    x = r.randn(256).astype(np.float32) * 2
    q = np.asarray(quantize(jnp.asarray(x), fp))
    # grid membership: q * 2^F integral
    scaled = q * fp.scale
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-3)
    # range respected
    assert q.max() <= fp.max_value + 1e-6
    assert q.min() >= fp.min_value - 1e-6
    # error bound for in-range values
    inr = (x < fp.max_value) & (x > fp.min_value)
    assert np.abs(q[inr] - x[inr]).max() <= fixed_point_error_bound(fp) + 1e-6


def test_saturation_vs_wrap():
    fp_sat = FixedPointConfig(8, 4, saturation="sat")
    x = jnp.asarray([100.0, -100.0])
    q = np.asarray(quantize(x, fp_sat))
    assert q[0] == pytest.approx(fp_sat.max_value)
    assert q[1] == pytest.approx(fp_sat.min_value)


def test_truncation_mode_rounds_down():
    fp = FixedPointConfig(8, 4, rounding="trn")
    q = float(quantize(jnp.asarray([0.99 / 16 + 0.3]), fp)[0])
    # floor to the grid below
    assert q <= 0.3 + 0.99 / 16


def test_host_and_device_quantizers_agree():
    fp = FixedPointConfig(16, 6)
    x = np.random.RandomState(0).randn(128).astype(np.float32) * 4
    a = quantize_np(x, fp)
    b = np.asarray(quantize(jnp.asarray(x), fp))
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_more_fractional_bits_reduce_error():
    r = np.random.RandomState(1)
    x = jnp.asarray(r.randn(512).astype(np.float32))
    errs = []
    for fb in (2, 4, 8, 12):
        fp = FixedPointConfig(6 + fb, 6)
        errs.append(float(jnp.abs(quantize(x, fp) - x).max()))
    assert all(a >= b for a, b in zip(errs, errs[1:]))


def test_saturates_diagnostic():
    fp = FixedPointConfig(8, 2)
    x = jnp.asarray([0.0, 0.5, 10.0, -10.0])
    assert float(saturates(x, fp)) == pytest.approx(0.5)


# -- AUC machinery ------------------------------------------------------------

def test_binary_auc_perfect_and_random():
    y = np.array([0, 0, 1, 1])
    assert binary_auc(np.array([0.1, 0.2, 0.8, 0.9]), y) == 1.0
    assert binary_auc(np.array([0.9, 0.8, 0.2, 0.1]), y) == 0.0
    assert binary_auc(np.array([0.5, 0.5, 0.5, 0.5]), y) == pytest.approx(0.5)


def test_multiclass_auc():
    probs = np.eye(3)[np.array([0, 1, 2, 0, 1, 2])] * 0.9 + 0.03
    y = np.array([0, 1, 2, 0, 1, 2])
    assert multiclass_mean_auc(probs, y) == 1.0
