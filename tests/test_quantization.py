"""ap_fixed emulation properties + PTQ machinery (paper Sec. 5.1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import FixedPointConfig
from repro.core.quant.fixed_point import (fixed_point_error_bound, quantize,
                                          quantize_np, saturates)
from repro.core.quant.ptq import binary_auc, multiclass_mean_auc


@given(total=st.integers(4, 22), integer=st.integers(1, 10))
@settings(max_examples=20, deadline=None)
def test_quantize_idempotent(total, integer):
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    x = jnp.asarray(np.random.RandomState(total).randn(64).astype(np.float32))
    q1 = quantize(x, fp)
    q2 = quantize(q1, fp)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@given(total=st.integers(4, 20), integer=st.integers(1, 10))
@settings(max_examples=20, deadline=None)
def test_quantize_on_grid_and_bounded_error(total, integer):
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    r = np.random.RandomState(integer * 7 + total)
    x = r.randn(256).astype(np.float32) * 2
    q = np.asarray(quantize(jnp.asarray(x), fp))
    # grid membership: q * 2^F integral
    scaled = q * fp.scale
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-3)
    # range respected
    assert q.max() <= fp.max_value + 1e-6
    assert q.min() >= fp.min_value - 1e-6
    # error bound for in-range values
    inr = (x < fp.max_value) & (x > fp.min_value)
    assert np.abs(q[inr] - x[inr]).max() <= fixed_point_error_bound(fp) + 1e-6


def test_saturation_vs_wrap():
    fp_sat = FixedPointConfig(8, 4, saturation="sat")
    x = jnp.asarray([100.0, -100.0])
    q = np.asarray(quantize(x, fp_sat))
    assert q[0] == pytest.approx(fp_sat.max_value)
    assert q[1] == pytest.approx(fp_sat.min_value)


def test_truncation_mode_rounds_down():
    fp = FixedPointConfig(8, 4, rounding="trn")
    q = float(quantize(jnp.asarray([0.99 / 16 + 0.3]), fp)[0])
    # floor to the grid below
    assert q <= 0.3 + 0.99 / 16


def test_host_and_device_quantizers_agree():
    fp = FixedPointConfig(16, 6)
    x = np.random.RandomState(0).randn(128).astype(np.float32) * 4
    a = quantize_np(x, fp)
    b = np.asarray(quantize(jnp.asarray(x), fp))
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_more_fractional_bits_reduce_error():
    r = np.random.RandomState(1)
    x = jnp.asarray(r.randn(512).astype(np.float32))
    errs = []
    for fb in (2, 4, 8, 12):
        fp = FixedPointConfig(6 + fb, 6)
        errs.append(float(jnp.abs(quantize(x, fp) - x).max()))
    assert all(a >= b for a, b in zip(errs, errs[1:]))


def test_saturates_diagnostic():
    fp = FixedPointConfig(8, 2)
    x = jnp.asarray([0.0, 0.5, 10.0, -10.0])
    assert float(saturates(x, fp)) == pytest.approx(0.5)


# -- property tests: host/device agreement, saturation, error bound ----------


@given(total=st.integers(4, 22), integer=st.integers(1, 10),
       rnd=st.booleans(), sat=st.booleans())
@settings(max_examples=30, deadline=None)
def test_quantize_and_quantize_np_agree(total, integer, rnd, sat):
    """One grid derivation (grid_constants/_apply_grid): the host f64 and
    device f32 quantizers agree on every (W, I, rounding, saturation)."""
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer,
                          rounding="rnd" if rnd else "trn",
                          saturation="sat" if sat else "wrap")
    x = np.random.RandomState(total * 31 + integer).randn(256) \
        .astype(np.float32) * 3
    a = quantize_np(x, fp)
    b = np.asarray(quantize(jnp.asarray(x), fp))
    np.testing.assert_allclose(a, b, atol=1e-6)


@given(total=st.integers(4, 20), integer=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_saturates_consistent_with_clip_range(total, integer):
    """saturates() flags exactly the entries quantize() clamps to a rail."""
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    x = np.random.RandomState(total + 99 * integer).randn(256) \
        .astype(np.float32) * (2.0 ** integer)
    frac = float(saturates(jnp.asarray(x), fp))
    outside = float(np.mean((x > fp.max_value) | (x < fp.min_value)))
    assert frac == pytest.approx(outside)
    # every flagged entry lands ON a rail after quantization
    q = quantize_np(x, fp)
    mask = (x > fp.max_value) | (x < fp.min_value)
    if mask.any():
        rails = np.isin(q[mask], [fp.max_value, fp.min_value])
        assert rails.all()


@given(total=st.integers(4, 20), integer=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_error_bound_bounds_round_trip(total, integer):
    """fixed_point_error_bound is a true bound on the quantization error of
    every in-range value — and tight within 2x (some value comes within a
    factor of two of it)."""
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    r = np.random.RandomState(total * 7 + integer)
    span = min(float(fp.max_value), 4.0)
    x = (r.rand(512).astype(np.float32) * 2 - 1) * span
    q = quantize_np(x, fp)
    err = np.abs(q - x)
    bound = fixed_point_error_bound(fp)
    assert err.max() <= bound + 1e-7
    assert err.max() >= bound / 2 - 1e-7         # tightness witness


@given(total=st.integers(4, 8), integer=st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_native_int_round_trip(total, integer):
    """to_ints/from_ints: grid indices are the exact integer image of
    quantize() for every native-eligible config."""
    from repro.core.quant.fixed_point import from_ints, to_ints

    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    x = jnp.asarray(np.random.RandomState(total).randn(128)
                    .astype(np.float32))
    q = quantize(x, fp)
    i = to_ints(q, fp)
    assert np.asarray(i).min() >= -(2 ** (total - 1))
    assert np.asarray(i).max() <= 2 ** (total - 1) - 1
    np.testing.assert_array_equal(np.asarray(from_ints(i, fp)),
                                  np.asarray(q))


# -- Pallas quantizer cross-check (single source of truth) --------------------


def _registered_fp_grid():
    """Every (W, I, rounding, saturation) combination the cross-check pins —
    the paper's grid plus the native-int configs plus trn/wrap corners."""
    fps = [FixedPointConfig(16, 6), FixedPointConfig(8, 3),
           FixedPointConfig(4, 2), FixedPointConfig(12, 4),
           FixedPointConfig(16, 6, rounding="trn"),
           FixedPointConfig(8, 4, saturation="wrap"),
           FixedPointConfig(10, 3, rounding="trn", saturation="wrap")]
    return fps


@pytest.mark.parametrize("fp", _registered_fp_grid(),
                         ids=lambda fp: f"ap{fp.total_bits}_{fp.integer_bits}"
                         f"_{fp.rounding}_{fp.saturation}")
def test_fixed_point_pallas_matches_reference_quantizer(fp):
    """The Pallas kernel body delegates to core.quant.fixed_point.quantize
    (one scale/clip derivation): every registered config — including
    truncation and wrap modes it used to silently ignore — must match both
    the device and host quantizers exactly."""
    from repro.kernels.fixed_point import fixed_point_pallas

    x = jnp.asarray(np.random.RandomState(fp.total_bits).randn(64, 32)
                    .astype(np.float32) * 4)
    got = np.asarray(fixed_point_pallas(x, fp, interpret=True))
    np.testing.assert_array_equal(got, np.asarray(quantize(x, fp)))
    np.testing.assert_allclose(got, quantize_np(np.asarray(x), fp),
                               atol=1e-6)


def test_ops_fixed_point_wrapper_matches():
    from repro.kernels import ops

    fp = FixedPointConfig(8, 3)
    x = jnp.asarray(np.random.RandomState(3).randn(5, 7, 16)
                    .astype(np.float32))
    np.testing.assert_array_equal(np.asarray(ops.fixed_point(x, fp)),
                                  np.asarray(quantize(x, fp)))


# -- AUC machinery ------------------------------------------------------------

def test_binary_auc_perfect_and_random():
    y = np.array([0, 0, 1, 1])
    assert binary_auc(np.array([0.1, 0.2, 0.8, 0.9]), y) == 1.0
    assert binary_auc(np.array([0.9, 0.8, 0.2, 0.1]), y) == 0.0
    assert binary_auc(np.array([0.5, 0.5, 0.5, 0.5]), y) == pytest.approx(0.5)


def test_multiclass_auc():
    probs = np.eye(3)[np.array([0, 1, 2, 0, 1, 2])] * 0.9 + 0.03
    y = np.array([0, 1, 2, 0, 1, 2])
    assert multiclass_mean_auc(probs, y) == 1.0
