"""HLS design-space model vs every latency number printed in the paper
(Tables 2-5) + the scaling laws of Figs 3-6."""

import pytest

from repro.config import FixedPointConfig
from repro.core.hls import RNNDesignPoint, estimate_design
from repro.registry import get_config

FP16 = FixedPointConfig(16, 6)

# (reuse_kernel, reuse_recurrent) -> (min_us, max_us) from the paper
TABLE_2 = {  # top tagging
    "gru": {(6, 5): (2.4, 6.5), (12, 10): (3.2, 7.3),
            (30, 20): (5.0, 9.1), (60, 60): (8.0, 12.1)},
    "lstm": {(6, 5): (2.7, 6.8), (12, 10): (3.5, 7.6),
             (30, 20): (5.3, 9.4), (60, 40): (8.3, 12.4)},
}
TABLE_3 = {  # flavor tagging (GRU row)
    (48, 40): (6.7, 24.8), (90, 60): (9.8, 27.9),
    (120, 120): (11.5, 29.6), (240, 240): (20.5, 38.6),
}
TABLE_4 = {  # quickdraw (GRU row)
    (48, 32): (35.4, 164.0), (96, 64): (59.4, 188.0),
    (192, 128): (107.0, 235.0), (384, 384): (203.0, 331.0),
}


def _check(design, lo, hi, tol=0.12):
    assert design.latency_min_us == pytest.approx(lo, rel=tol)
    assert design.latency_max_us == pytest.approx(hi, rel=tol)


@pytest.mark.parametrize("cell", ["gru", "lstm"])
def test_table_2_top_tagging_latencies(cell):
    cfg = get_config(f"top-tagging-{cell}")
    for (rk, rr), (lo, hi) in TABLE_2[cell].items():
        _check(estimate_design(RNNDesignPoint(cfg, FP16, rk, rr)), lo, hi)


def test_table_3_flavor_tagging_latencies():
    cfg = get_config("flavor-tagging-gru")
    for (rk, rr), (lo, hi) in TABLE_3.items():
        _check(estimate_design(RNNDesignPoint(cfg, FP16, rk, rr)), lo, hi)


def test_table_4_quickdraw_latencies():
    cfg = get_config("quickdraw-gru")
    for (rk, rr), (lo, hi) in TABLE_4.items():
        _check(estimate_design(RNNDesignPoint(
            cfg, FixedPointConfig(26, 10), rk, rr, part="u250")), lo, hi)


def test_table_5_static_vs_nonstatic_ii():
    cfg = get_config("top-tagging-gru")
    st = estimate_design(RNNDesignPoint(cfg, FixedPointConfig(10, 6),
                                        strategy="latency", mode="static"))
    ns = estimate_design(RNNDesignPoint(cfg, FixedPointConfig(10, 6),
                                        strategy="latency", mode="nonstatic"))
    assert ns.ii_cycles == 1                       # paper: II -> 1
    assert st.ii_cycles == pytest.approx(315, rel=0.1)  # paper: 315
    # >300x throughput gain (paper Sec 5.3)
    assert ns.throughput_eps / st.throughput_eps > 300
    # latencies comparable
    assert ns.latency_min_us == pytest.approx(st.latency_min_us, rel=0.15)


def test_fig_6_nonstatic_fits_only_small_widths():
    cfg = get_config("top-tagging-gru")
    fits = {}
    for W in (10, 16, 22):
        d = estimate_design(RNNDesignPoint(cfg, FixedPointConfig(W, 6),
                                           strategy="latency",
                                           mode="nonstatic"))
        fits[W] = d.fits
    assert fits[10] and not fits[16] and not fits[22]


def test_fig_3_dsp_flat_then_doubles():
    cfg = get_config("top-tagging-gru")
    d12 = estimate_design(RNNDesignPoint(cfg, FixedPointConfig(12, 6), 6, 5))
    d18 = estimate_design(RNNDesignPoint(cfg, FixedPointConfig(18, 6), 6, 5))
    d22 = estimate_design(RNNDesignPoint(cfg, FixedPointConfig(22, 6), 6, 5))
    assert d12.dsp == d18.dsp                      # flat until DSP width
    assert d22.dsp == 2 * d18.dsp                  # then doubles


def test_resource_scaling_laws():
    cfg_g = get_config("top-tagging-gru")
    cfg_l = get_config("top-tagging-lstm")
    a = estimate_design(RNNDesignPoint(cfg_g, FP16, 6, 5))
    b = estimate_design(RNNDesignPoint(cfg_g, FP16, 12, 10))
    assert a.dsp == pytest.approx(2 * b.dsp, rel=0.05)   # 1/R DSP scaling
    l = estimate_design(RNNDesignPoint(cfg_l, FP16, 6, 5))
    assert 1.1 < l.dsp / a.dsp < 1.45             # GRU ~3/4 of LSTM (Sec 5.2)
    ns = estimate_design(RNNDesignPoint(cfg_g, FP16, 6, 5, mode="nonstatic"))
    assert ns.dsp == 20 * a.dsp                   # x seq_len (Fig 6)


def test_quickdraw_throughput_overlaps_paper_range():
    """Paper Sec 5.2: QuickDraw LSTM II-derived throughput 4300-9700 ev/s."""
    cfg = get_config("quickdraw-lstm")
    tputs = [estimate_design(RNNDesignPoint(
        cfg, FixedPointConfig(26, 10), rk, rr, part="u250")).throughput_eps
        for (rk, rr) in TABLE_4]
    assert min(tputs) < 4300 * 1.3
    assert any(4300 * 0.7 <= t <= 9700 * 1.3 for t in tputs)
